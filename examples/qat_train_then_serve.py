"""End-to-end driver: QAT-train a ~100M-param LM, convert to FP4, serve it.

This is the full ZettaLith deployment story (paper Section 4: transformers
must be "effectively trained in FP4 using QAT" before the rack can serve
them): train with FP4 fake-quant -> PTQ convert -> FP4 continuous-batching
serving, with checkpoints along the way.

Default (CI-sized, a few minutes on CPU):
    PYTHONPATH=src python examples/qat_train_then_serve.py
Full ~100M / few hundred steps (as the deliverable spec describes):
    PYTHONPATH=src python examples/qat_train_then_serve.py --full
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import cascade
from repro.core.cascade import CascadeConfig
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import registry
from repro.optim.adamw import AdamW
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.train import checkpoint as ckpt
from repro.train import loop as train_loop


def make_arch(full: bool) -> ArchConfig:
    if full:  # ~100M params (qwen-family block structure)
        return ArchConfig(name="repro-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
                          d_ff=2048, vocab=8192, qkv_bias=True)
    return ArchConfig(name="repro-8m", family="dense", n_layers=4,
                      d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
                      d_ff=512, vocab=2048, qkv_bias=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_qat_ckpt")
    args = ap.parse_args()

    cfg = make_arch(args.full)
    steps = args.steps or (300 if args.full else 60)
    batch, seq = (16, 256) if args.full else (8, 64)

    model = registry.build_model(cfg)
    ccfg = CascadeConfig(mode="train", qat=True, compute_dtype=jnp.float32)
    opt = AdamW(lr=1e-3, warmup_steps=steps // 10, decay_steps=steps)
    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch))

    n_params = sum(v.size for v in jax.tree.leaves(
        jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), ccfg))))
    print(f"arch {cfg.name}: {n_params/1e6:.1f}M params, QAT for {steps} steps")

    state = train_loop.init_state(model, ccfg, opt)
    step_fn = jax.jit(train_loop.make_train_step(model, ccfg, opt, remat=False))
    t0, first = time.time(), None
    for i in range(steps):
        state, m = step_fn(state, jax.tree.map(jnp.asarray, data.batch_at(i)))
        loss = float(m["loss"])
        first = first or loss
        if i % max(1, steps // 10) == 0 or i == steps - 1:
            print(f"  step {i:4d} loss {loss:.4f}")
        if (i + 1) % max(10, steps // 3) == 0:
            ckpt.save(state, args.ckpt_dir, i + 1, extra={"data_step": i + 1},
                      async_=True)
    print(f"QAT done: loss {first:.3f} -> {loss:.3f} in {time.time()-t0:.0f}s")
    assert loss < first, "QAT training failed to reduce loss"

    # ---- PTQ convert: the QAT weights survive FP4 quantization -------------
    serve_ccfg = dataclasses.replace(ccfg, mode="serve_fp4", qat=False)
    fp4_params = cascade.tree_to_serve_fp4(state.params, serve_ccfg)

    val = jax.tree.map(jnp.asarray, data.batch_at(steps + 1))
    def ce(p, c):
        logits = model.forward(p, val, c)
        return float(train_loop.cross_entropy(logits, val["labels"]))
    ce_train, ce_fp4 = ce(state.params, dataclasses.replace(ccfg, qat=False)), \
        ce(fp4_params, serve_ccfg)
    print(f"val CE: dense {ce_train:.4f} vs FP4-served {ce_fp4:.4f} "
          f"(delta {ce_fp4-ce_train:+.4f} — QAT makes FP4 nearly free)")

    # ---- serve with continuous batching ------------------------------------
    eng = ServeEngine(model, fp4_params, serve_ccfg,
                      ServeConfig(max_batch=4, max_len=seq + 24))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
                    max_new_tokens=8) for i in range(8)]
    for r in reqs:
        eng.submit(r)
    t0, tokens = time.time(), 0
    while eng.busy():
        tokens += eng.step()
    print(f"served {len(reqs)} requests / {tokens} tokens in {time.time()-t0:.1f}s "
          f"from FP4 weights; sample: {reqs[0].tokens_out}")


if __name__ == "__main__":
    main()

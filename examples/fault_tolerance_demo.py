"""Fault-tolerance demo: the three ZettaLith reliability layers in software.

1. **CREST** (paper Sections 20-21): inject defective output columns into a
   serving matmul; the cyclic spare-column tester detects them (filtering a
   transient "cosmic ray"), repairs via spare recomputation, zero accuracy
   loss afterwards.
2. **Fail-in-place** (Section 20): kill one serving replica mid-flight; its
   requests are re-queued to survivors and all complete.
3. **Checkpoint/restart** (Section 21.3 contrast): crash training at step k,
   restore, and show the loss trajectory is bit-identical.

Run: PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crest
from repro.core.cascade import CascadeConfig
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import registry
from repro.optim.adamw import AdamW
from repro.serve.elastic import ReplicaSet
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.train import checkpoint as ckpt
from repro.train import loop as train_loop


def demo_crest():
    print("=== 1. CREST: cyclic redundant spare testing =====================")
    cfg = crest.CrestConfig(n_spares=8, threshold=3)
    k, n = 64, 128
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.2
    faults = crest.inject_column_faults(jax.random.PRNGKey(1), n, 5)
    print(f"injected defective PE columns: {np.where(np.asarray(faults))[0]}")
    state = crest.crest_init(n, cfg)
    step = jax.jit(lambda x, s, f: crest.crest_matmul(x, w, s, cfg, f))
    for i in range(80):
        x = jax.random.normal(jax.random.PRNGKey(100 + i), (16, k))
        # a one-step transient glitch on column 0 at i==10 (cosmic ray)
        f = faults.at[0].set(True) if i == 10 else faults
        y, state = step(x, state, f)
    stats = crest.coverage_stats(state, faults)
    print(f"detected {stats['detected']}/{stats['injected']}, "
          f"false positives {stats['false_positives']} "
          f"(transient correctly filtered), repaired {stats['repaired']}")
    x = jax.random.normal(jax.random.PRNGKey(999), (16, k))
    y, _ = step(x, state, faults)
    print(f"post-repair max error vs clean matmul: "
          f"{float(jnp.max(jnp.abs(y - x @ w))):.2e}")
    print(f"overhead: 2*{cfg.n_spares}/{n} = {2*cfg.n_spares/n:.1%} extra columns "
          f"(paper: 16/8208 = 0.2%)\n")


def demo_fail_in_place():
    print("=== 2. fail-in-place: replica loss under load ====================")
    cfg, model = registry.load("codeqwen1.5-7b", smoke=True)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), ccfg)
    engines = [ServeEngine(model, params, ccfg, ServeConfig(max_batch=2, max_len=48))
               for _ in range(3)]
    rs = ReplicaSet(engines)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=6) for i in range(9)]
    for r in reqs:
        rs.submit(r)
    rs.step()
    print("killing replica 0 with requests in flight...")
    rs.kill_replica(0)
    rs.drain(max_steps=300)
    done = {r.uid for r in reqs if r.done} | {r.uid for r in rs.requeued if r.done}
    print(f"completed {len(done)}/9 requests after failover "
          f"(healthy replicas: {[i for i, h in enumerate(rs.health) if h.alive]})\n")


def demo_checkpoint_restart():
    print("=== 3. checkpoint/restart: bit-identical resume ==================")
    cfg, model = registry.load("phi4-mini-3.8b", smoke=True)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    opt = AdamW(lr=1e-3, warmup_steps=2, decay_steps=10)
    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2))
    step_fn = jax.jit(train_loop.make_train_step(model, ccfg, opt, remat=False))
    with tempfile.TemporaryDirectory() as d:
        state = train_loop.init_state(model, ccfg, opt)
        a = []
        for i in range(6):
            if i == 3:
                ckpt.save(state, d, i, extra={"data_step": i})
            state, m = step_fn(state, jax.tree.map(jnp.asarray, data.batch_at(i)))
            a.append(float(m["loss"]))
        sb = train_loop.init_state(model, ccfg, opt)
        sb, extra = ckpt.restore(sb, d)
        b = []
        for i in range(int(extra["data_step"]), 6):
            sb, m = step_fn(sb, jax.tree.map(jnp.asarray, data.batch_at(i)))
            b.append(float(m["loss"]))
        print(f"uninterrupted tail:   {a[3:]}")
        print(f"crash+restore tail:   {b}")
        assert np.allclose(a[3:], b, rtol=1e-6)
        print("bit-identical resume confirmed\n")


if __name__ == "__main__":
    demo_crest()
    demo_fail_in_place()
    demo_checkpoint_restart()

"""Quickstart: the paper's FP4 CASCADE pipeline in 60 lines.

1. Build a small transformer, 2. PTQ its weights to packed FP4 E2M1,
3. serve a batch with the CASCADE (column-parallel, no-partial-sum) matmul
   path, 4. verify against the bf16 reference and the bit-accurate
   FP8-accumulation oracle.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import cascade, quant
from repro.core.cascade import CascadeConfig
from repro.kernels import ops
from repro.models import registry


def main():
    # --- 1. a reduced qwen2.5-family model ---------------------------------
    cfg, model = registry.load("qwen2.5-32b", smoke=True)
    train_ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), train_ccfg)

    # --- 2. PTQ -> packed FP4 (0.5 bytes/weight in HBM) --------------------
    serve_ccfg = dataclasses.replace(train_ccfg, mode="serve_fp4")
    fp4_params = cascade.tree_to_serve_fp4(params, serve_ccfg)
    dense_b = sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(params))
    fp4_b = sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(fp4_params))
    print(f"weights: {dense_b/1e6:.2f} MB dense -> {fp4_b/1e6:.2f} MB FP4-packed")

    # --- 3. serve a batch ---------------------------------------------------
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits_fp4 = model.forward(fp4_params, {"tokens": tokens}, serve_ccfg)
    logits_ref = model.forward(params, {"tokens": tokens}, train_ccfg)
    rel = float(jnp.max(jnp.abs(logits_fp4 - logits_ref)) / jnp.max(jnp.abs(logits_ref)))
    # exactness claim: the FP4 path == dense forward of PTQ-roundtripped weights
    qdq = cascade.tree_to_serve_fp4(params, serve_ccfg)
    from repro.core import quant as Q
    def rt(d):
        if isinstance(d, dict) and "codes" in d:
            out = {"w": jax.vmap(lambda c, s: Q.dequantize_weight(c, s, jnp.float32))(
                d["codes"], d["scale"]) if d["codes"].ndim == 3 else
                Q.dequantize_weight(d["codes"], d["scale"], jnp.float32)}
            if "b" in d: out["b"] = d["b"]
            return out
        if isinstance(d, dict): return {k: rt(v) for k, v in d.items()}
        if isinstance(d, list): return [rt(v) for v in d]
        return d
    logits_qdq = model.forward(rt(qdq), {"tokens": tokens}, train_ccfg)
    exact = float(jnp.max(jnp.abs(logits_fp4 - logits_qdq)) / (jnp.max(jnp.abs(logits_qdq)) + 1e-9))
    print(f"FP4 path vs PTQ-roundtripped dense (exactness): {exact:.2e}")
    print(f"FP4 vs original bf16 (PTQ noise on a RANDOM-init net): {rel:.3f} — "
          f"random nets amplify quant noise; QAT closes this "
          f"(see examples/qat_train_then_serve.py: delta ~1e-2 CE)")

    # --- 4. one CASCADE matmul, three ways ----------------------------------
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 64)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 128))
    packed, scales = quant.quantize_weight(w, group_size=64)
    y_kernel = ops.cascade_matmul(x, packed, scales, block_m=8, block_n=64,
                                  block_k=64, interpret=True)
    y_ref = ops.cascade_matmul_ref(x, packed, scales)
    w4 = quant.dequantize_weight(packed, scales, jnp.float32)
    print(f"Pallas kernel vs ref: {float(jnp.max(jnp.abs(y_kernel - y_ref))):.2e}")
    print("bit-accurate FP8-column-accumulation oracle (paper Table 6 dataflow):")
    xs = jnp.max(jnp.abs(x)) / quant.FP4_MAX
    x4 = quant.fp4_decode(quant.fp4_encode(x / xs))
    y_exact = quant.cascade_matmul_exact(x4, w4 / jnp.max(jnp.abs(w4)) * quant.FP4_MAX)
    print(f"  column sums saturate at +/-{quant.FP8_E4M3_MAX}, "
          f"max |sum| = {float(jnp.max(jnp.abs(y_exact))):.1f}")


if __name__ == "__main__":
    main()

"""Open-loop traffic generation and the serving-path clock protocol.

ZettaLith's premise is a rack serving inference for millions of users
(paper Sections 2, 19): the metric that separates rack-scale serving from
batch benchmarks is tail latency under an arrival process the system does
NOT control. This module provides both halves of that measurement:

* **Clocks** — every serving-path latency number (request timestamps,
  admission waits, step times, replica EWMAs) reads an injected ``Clock``
  instead of ``time.monotonic`` directly. ``MonotonicClock`` is the
  wall-clock default; ``VirtualClock`` is a manually-advanced deterministic
  clock, so a traffic test replays the SAME per-request TTFT/inter-token
  records on every run (the harness — ``serve/router.py`` /
  ``ReplicaSet.step_cost`` — advances it; the engines only read it).

* **The generator** — ``poisson_trace`` builds a seeded OPEN-LOOP trace:
  Poisson arrivals (i.i.d. exponential inter-arrival times at
  ``rate_rps``), mixed prompt/output-length distributions (a weighted
  mixture of uniform integer ranges — the short-interactive + long-batch
  shape of real multi-tenant traffic), and per-request SLOs (a TTFT target
  and an admission deadline after which the request should be shed rather
  than served uselessly late). Open-loop means arrivals NEVER wait for the
  system: each request's ``created_at`` is stamped with its arrival time
  at generation, so queueing delay under overload shows up in TTFT instead
  of silently throttling the offered load (closed-loop benchmarks measure
  the generator, not the server).

Same seed => identical trace (arrival times, prompts, lengths, SLOs) —
pinned by ``tests/test_traffic.py`` property tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Protocol, runtime_checkable

import numpy as np


# --------------------------------------------------------------------- clocks
@runtime_checkable
class Clock(Protocol):
    """What the serving path needs from a time source: ``now()`` seconds,
    and ``sleep_until(t)`` for idle gaps (the router parks on it between
    scheduled events — a wall clock really sleeps, a virtual clock jumps).

    Monotone non-decreasing; the zero point is arbitrary (only differences
    are ever used). Nothing in ``serve/`` outside this module may touch
    ``time.*`` directly (enforced by ``repro.analysis.lint``'s
    ``time-read`` rule), so deterministic traffic tests stay deterministic.
    """

    def now(self) -> float: ...

    def sleep_until(self, t: float) -> float: ...


class MonotonicClock:
    """Wall-clock default: ``time.monotonic`` behind the protocol."""

    def now(self) -> float:
        return time.monotonic()

    def sleep_until(self, t: float) -> float:
        time.sleep(max(0.0, t - time.monotonic()))
        return time.monotonic()


class VirtualClock:
    """Deterministic manual clock for traffic tests.

    ``now()`` returns the last value set; the HARNESS advances it
    (``advance``/``advance_to``) — e.g. ``ReplicaSet(step_cost=...)`` pays
    a configured virtual cost per replica step, and the router fast-forwards
    to the next arrival when the fleet idles. Engines only ever read it, so
    two runs of the same seeded trace produce byte-identical latency
    records."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        assert dt >= 0.0, f"virtual time cannot go backwards (dt={dt})"
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        """Jump forward to ``t`` (no-op if ``t`` is in the past)."""
        self._t = max(self._t, float(t))
        return self._t

    def sleep_until(self, t: float) -> float:
        """Virtual sleep is a jump: no wall time passes."""
        return self.advance_to(t)


# ------------------------------------------------------------------ generator
@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """A seeded open-loop workload.

    ``prompt_lens``/``output_lens`` are mixtures of inclusive ``(lo, hi)``
    integer ranges with ``prompt_mix``/``output_mix`` component weights
    (normalized; lengths are drawn uniformly inside the chosen component).
    ``slo_ttft_s`` and ``deadline_s`` stamp every request; 0 disables the
    respective SLO (no TTFT target / never shed)."""
    rate_rps: float = 8.0             # Poisson arrival rate (requests/s)
    n_requests: int = 32
    prompt_lens: tuple = ((4, 16),)   # mixture of inclusive [lo, hi] ranges
    prompt_mix: tuple = (1.0,)
    output_lens: tuple = ((4, 16),)
    output_mix: tuple = (1.0,)
    vocab: int = 256
    slo_ttft_s: float = 0.0           # per-request TTFT target (0 = none)
    deadline_s: float = 0.0           # admission deadline: shed if not yet
                                      # dispatched this long after arrival
                                      # (0 = never shed)
    # --- shared-system-prompt mixture (prefix-cache workloads) ---
    # A fraction of requests open with one of ``n_shared_prefixes`` fixed
    # token prefixes of ``shared_prefix_len`` tokens (drawn once per trace)
    # followed by their unique suffix — the multi-tenant "same system
    # prompt, different user turn" shape the radix prefix cache exists for.
    # Tagged requests carry ``prefix_id`` so benchmarks can split warm
    # (prefix already resident) from cold TTFT. 0 disables the mixture.
    shared_prefix_len: int = 0        # tokens in each shared prefix
    n_shared_prefixes: int = 1        # distinct shared prefixes in the pool
    shared_fraction: float = 1.0      # probability a request is tagged
    seed: int = 0


@dataclasses.dataclass
class Arrival:
    """One trace entry: a request and the instant it arrives (seconds from
    trace start, on whatever clock drives the run)."""
    at_s: float
    request: "object"                 # serve.engine.Request (deferred import)


def _mixture_lengths(rng: np.random.Generator, n: int, ranges: tuple,
                     mix: tuple) -> np.ndarray:
    """n integer lengths from a weighted mixture of inclusive ranges."""
    assert len(ranges) == len(mix) and len(ranges) >= 1, (ranges, mix)
    for lo, hi in ranges:
        assert 1 <= lo <= hi, f"bad length range ({lo}, {hi})"
    w = np.asarray(mix, np.float64)
    assert (w >= 0).all() and w.sum() > 0, f"bad mixture weights {mix}"
    comp = rng.choice(len(ranges), size=n, p=w / w.sum())
    lens = np.empty(n, np.int64)
    for j, (lo, hi) in enumerate(ranges):
        idx = comp == j
        lens[idx] = rng.integers(lo, hi + 1, size=int(idx.sum()))
    return lens


def poisson_trace(cfg: TrafficConfig) -> List[Arrival]:
    """Seeded open-loop trace: sorted arrivals with prompts, output budgets
    and SLO stamps. ``at_s`` is relative to the trace start; the driver
    (``SLORouter.run_trace``) re-bases it onto its clock's epoch and stamps
    each request's ``created_at`` with the re-based ARRIVAL time (not the
    later dispatch time), so queueing delay between arrival and dispatch is
    charged to TTFT — the open-loop contract."""
    from repro.serve.engine import Request   # deferred: engine imports clocks

    rng = np.random.default_rng(cfg.seed)
    assert cfg.rate_rps > 0 and cfg.n_requests > 0
    gaps = rng.exponential(1.0 / cfg.rate_rps, cfg.n_requests)
    at = np.cumsum(gaps)
    plens = _mixture_lengths(rng, cfg.n_requests, cfg.prompt_lens,
                             cfg.prompt_mix)
    olens = _mixture_lengths(rng, cfg.n_requests, cfg.output_lens,
                             cfg.output_mix)
    # shared-prefix pool: drawn AFTER the base draws (and only when the
    # mixture is on), so traces without it are byte-identical to before
    prefixes, tags = [], np.full(cfg.n_requests, -1, np.int64)
    if cfg.shared_prefix_len > 0:
        assert cfg.n_shared_prefixes >= 1
        prefixes = [rng.integers(0, cfg.vocab,
                                 cfg.shared_prefix_len).astype(np.int32)
                    for _ in range(cfg.n_shared_prefixes)]
        shared = rng.random(cfg.n_requests) < cfg.shared_fraction
        tags = np.where(shared,
                        rng.integers(0, cfg.n_shared_prefixes,
                                     cfg.n_requests), -1)
    trace = []
    for i in range(cfg.n_requests):
        prompt = rng.integers(0, cfg.vocab, int(plens[i])).astype(np.int32)
        if tags[i] >= 0:
            prompt = np.concatenate([prefixes[int(tags[i])], prompt])
        req = Request(uid=i,
                      prompt=prompt,
                      max_new_tokens=int(olens[i]),
                      slo_ttft_s=cfg.slo_ttft_s,
                      deadline_s=cfg.deadline_s,
                      prefix_id=int(tags[i]))
        trace.append(Arrival(at_s=float(at[i]), request=req))
    return trace

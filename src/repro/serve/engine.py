"""Serving engine: batched continuous batching over a fixed slot grid, FP4
weights, chunked prefill, CREST runtime fault detection, straggler guard.

The ZettaLith analogy (paper Sections 14, 19-20): a rack serves one model
from resident (HBM) FP4 weights; batch size is chosen to balance HBM weight
streaming against compute (Table 9/10); CREST continuously shadow-tests
columns; failed components are mapped out without draining traffic.

Software mapping: ``ServeEngine`` owns a slot grid of ``max_batch`` decode
streams backed by ONE stacked, fixed-shape KV cache pytree. Each step:

1. **admission** — queued requests are prefilled into free slots in
   ``prefill_chunk``-token pieces (fixed chunk shape => one compiled extend
   kernel for any prompt length), bounded by a per-step ``token_budget`` so
   decode latency for already-resident streams stays bounded;
2. **decode** — ONE donated, jitted batched ``decode_step`` runs over the
   whole slot grid (weight streaming is paid once per step, not once per
   request — the CASCADE batching analysis, Table 9/10); inactive slots
   compute masked garbage that never escapes. With ``draft_len > 0`` the
   step instead runs **speculative decode**: a model-free prompt-lookup
   drafter (``serve/spec.py``) proposes up to K tokens per slot (reporting
   the per-slot effective draft length ``k_eff`` — padding is never scored
   as a proposal), ONE batched verify pass (the fixed-shape
   ``prefill_extend`` path) scores all K+1 positions at once, accepted
   tokens commit (plus a bonus/resampled token), and the rejected suffix
   rolls back through per-family cache rewind ops (``spec_rewind``) —
   weight streaming is amortized over every accepted token. Acceptance
   depends on the decoding mode: under greedy the longest draft prefix
   matching the model's own argmax commits, and the emitted stream is
   token-exact with plain greedy decode; under sampling
   (``temperature > 0``) the step runs **speculative sampling** (rejection
   resampling, ``spec_sample_accept``) against the drafter's point-mass
   proposal distribution, so every committed token is distributed EXACTLY
   as plain sampled decode (distribution-exact, not token-exact — the
   draws differ but the law does not);
3. a CREST probe wave optionally shadow-tests the lm_head matmul;
4. finished streams retire by simply freeing their slot — admission and
   retirement are cache-slot writes, so nothing ever recompiles as traffic
   comes and goes.

**Mesh-native serving** (paper Sections 2.2, 13.5): pass ``mesh=`` (a
``(data, model)`` jax mesh) and the whole batched serving stack — decode,
chunked prefill, spec-verify, rewind — runs under ``NamedSharding``: params
are placed by the ``tp_policy`` (``cascade`` column-parallel, the paper's
layout, or the ``megatron`` row+column baseline) via
``distributed.sharding.param_specs``, and the stacked cache shards its
probe-discovered slot axis over ``data`` (``cache_pspecs`` — every data
shard owns a band of decode slots). Under the cascade policy the decode
step contains **zero partial-sum all-reduce** — activations are broadcast,
weights are column-sharded, reductions stay local — and
``decode_step_hlo()`` exposes the compiled HLO so
``benchmarks/hlo_analysis.partial_sum_allreduces`` can assert the paper's
headline interconnect claim as an executable test. The sharded path is
token-exact with the single-device path (contractions never split, so
accumulation order is unchanged).

Every registry arch family runs the batched fast path over its own cache
state:

==============  ===========================  ==============================
family          stacked state per slot       chunked prefill
==============  ===========================  ==============================
transformer     full-attention KV            fixed-shape padded chunks
moe (MLA/GQA)   latent c_kv + rope key / KV  fixed-shape padded chunks
griffin/hybrid  ring-buffer KV + {conv, h}   ring-aware (never clobbers
                                             in-window entries)
ssm (mamba2)    {conv, ssd state}            dt=0 passthrough padding
==============  ===========================  ==============================

Windowed/recurrent archs hold O(window)/O(1) state, so their admissible
prompt length is NOT bounded by ``max_len`` (window-aware admission) and
they never retire on a context limit. ``batched=False`` keeps the legacy
slot-wise loop as the parity baseline; multi-codebook heads (musicgen)
remain slot-wise. Decoding is greedy argmax by default; ``temperature`` /
``top_k`` switch on (deterministic, seeded) sampling — drawn ON DEVICE
everywhere (``jax.random.categorical`` fused into the jitted step for the
batched grid; a jitted single-row draw for the admission and slot-wise
paths) under ONE shared RNG discipline: draw i uses
``fold_in(PRNGKey(sample_seed), i)`` regardless of mode — a speculative
sampled step consumes exactly one counter value and derives its accept
uniforms and resample/bonus Gumbel noise from it on device. Any mode
downgrade (multi-codebook models dropping to the slot-wise grid, a model
missing the spec API, slot-wise engines dropping speculation) warns once
and is visible as ``metrics()['effective_mode']``. ``elastic.py`` handles
replica failure
by re-queueing in-flight requests (decode state — including recurrent
state — is reconstructible from the prompt + emitted tokens; ``tokens_out``
only ever holds verify-committed tokens, so a failover can never carry an
unaccepted draft).
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crest
from repro.core.cascade import CascadeConfig
from repro.distributed import sharding as shd
from repro.models.cache_utils import reset_slot_pos
from repro.serve.prefix import PagePool, RadixPrefixCache
from repro.serve.spec import ngram_propose
from repro.serve.traffic import MonotonicClock

#: methods a model must expose for the batched (stacked-cache) fast path
#: (``stack_caches``/``cache_at`` are companion utilities on the model, but
#: the engine itself only needs slot writes + chunked extend)
_BATCHED_API = ("write_cache", "prefill_extend")

#: additional methods required for speculative decode (verify pass + per-
#: family cache rewind)
_SPEC_API = ("spec_verify", "spec_rewind")


def _truncate_logits(logits, temperature: float, top_k: int):
    """Temperature-scale + top-k-truncate logits; ``softmax`` of the result
    is THE sampling distribution p every sampled path draws from.

    Works on any ``(..., V)`` shape (decode rows are ``(B, V)``; the
    speculative verify pass truncates all ``(B, K+1, V)`` rows at once —
    acceptance must score drafts against the IDENTICAL truncated p that
    plain decode samples from, or the committed distribution drifts).

    **Tie semantics (documented, pinned by tests):** the truncated support
    is VALUE-defined, not count-defined — every logit ``>= kth`` survives,
    so a tie at the k-th logit keeps all tied candidates (more than k).
    This makes the truncation a pure function of the logit values (no
    arbitrary index-order tie-break that plain decode and the verify pass
    could resolve differently), which is what distribution-exact
    speculative sampling requires. Corollary: ``top_k=1`` equals greedy
    only when the max is unique.

    Under a cascade mesh policy the rows are pinned replicated first (one
    small all-gather): top-k / softmax / the Gumbel add over a
    vocab-sharded row would otherwise lower to a partial-sum all-reduce,
    breaking the zero-AR invariant for sampled serving.
    """
    x = shd.constrain_replicated(logits).astype(jnp.float32) / temperature
    if 0 < top_k < x.shape[-1]:
        kth = jax.lax.top_k(x, top_k)[0][..., -1:]
        x = jnp.where(x < kth, -jnp.inf, x)
    return x


def _sample_tokens(logits, key, temperature: float, top_k: int):
    """(B, V) logits -> (B,) sampled token ids, entirely on device.

    Each row's draw is a pure function of (key, row index): the Gumbel
    noise is positional, so an active slot's sample never depends on what
    garbage the inactive slots hold.
    """
    return jax.random.categorical(
        key, _truncate_logits(logits, temperature, top_k), axis=-1)


#: large finite logit penalty used to zero the rejected draft's mass in the
#: residual. Finite on purpose: if the residual is EMPTY (p was numerically
#: a point mass on the draft, so every other logit is already -inf), the
#: penalized draft still wins the categorical — which is the correct
#: degenerate action, because an empty residual means the acceptance
#: probability was 1 and the "rejection" was a measure-zero float artifact.
_RESIDUAL_PENALTY = 1e30


def spec_sample_accept(logits, drafts, k_eff, key, temperature: float,
                       top_k: int):
    """Speculative-sampling acceptance for a point-mass (delta) drafter.

    Args: ``logits`` (B, K+1, V) verify-pass rows (row j conditions on the
    cache prefix + chunk tokens 0..j); ``drafts`` (B, K) proposed tokens
    (chunk tokens 1..K); ``k_eff`` (B,) real-proposal counts (positions
    >= k_eff are padding and are force-rejected, never scored); ``key`` ONE
    fold_in counter value — accept uniforms and the resample/bonus draw are
    derived from it on device, positionally per slot.

    Returns ``(a, token)``: ``a`` (B,) accepted draft counts and ``token``
    (B,) the step's final committed token. The standard rule, specialized
    to q = delta(d):

    * accept draft d_j with probability ``min(1, p_j(d_j) / q_j(d_j))`` =
      ``p_j(d_j)`` (q is a point mass, so the clamp never binds; p is the
      truncated softmax ``_truncate_logits`` defines — identical to what
      plain sampled decode draws from);
    * first rejection at row a: resample from the residual
      ``norm(max(0, p_a - q_a))`` — for a delta q that is p_a with the
      rejected token's mass removed, i.e. a logit-space mask of d_a;
    * all k_eff real drafts accepted: the bonus token is drawn from row
      ``k_eff`` (NOT row K when k_eff < K — later rows condition on padded
      tokens that were never proposed).

    Marginal of the committed token at any row: ``p(d)·1[t=d] +
    (1-p(d))·p(t)/(1-p(d)) = p(t)`` — exactly the plain sampled-decode
    distribution, which is the tentpole's distribution-exactness argument
    (enumerated per family by ``tests/test_spec.py``).
    """
    b, kp1, v = logits.shape
    k = kp1 - 1
    x = _truncate_logits(logits, temperature, top_k)        # (B, K+1, V)
    logp = jax.nn.log_softmax(x, axis=-1)
    # p_j(d_j): the truncated model probability of each draft token
    p_draft = jnp.exp(jnp.take_along_axis(
        logp[:, :k], drafts[..., None], axis=-1)[..., 0])   # (B, K)
    key_u, key_t = jax.random.split(key)
    u = jax.random.uniform(key_u, (b, k))
    real = jnp.arange(k, dtype=jnp.int32)[None, :] < k_eff[:, None]
    accept = (u < p_draft) & real
    # leading-accept count: stop at the first rejection (or at k_eff)
    a = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=-1), axis=-1)
    # final row: the rejection row (a < k_eff) or the bonus row (a == k_eff)
    row = jnp.take_along_axis(x, a[:, None, None], axis=1)[:, 0]  # (B, V)
    rejected = a < k_eff
    d_rej = jnp.take_along_axis(drafts, jnp.minimum(a, k - 1)[:, None],
                                axis=1)[:, 0]               # (B,)
    mask = (jnp.arange(v, dtype=jnp.int32)[None, :] == d_rej[:, None])
    row = row - jnp.where(mask & rejected[:, None], _RESIDUAL_PENALTY, 0.0)
    token = jax.random.categorical(key_t, row, axis=-1)
    return a, token


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    created_at: float = 0.0       # arrival: pre-stamped by an open-loop
                                  # driver, else set at first submit()
    admitted_at: float = 0.0      # when prefill started (admission wait ends)
    first_token_at: float = 0.0
    finished_at: float = 0.0
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False
    prompt_carried: int = 0       # leading tokens_out entries already baked
                                  # into ``prompt`` by a failover rebuild
    # --- per-request latency telemetry (the engine's injected clock) ---
    token_times: list = dataclasses.field(default_factory=list)
    #: token_times[i] is the clock reading when tokens_out[i] was COMMITTED
    #: (post-verification under speculation — a spec step commits its whole
    #: accepted run at one instant, which is the honest burst semantics).
    #: TTFT = first_token_at - created_at; inter-token latencies are the
    #: successive differences of token_times. Failover carries both lists,
    #: so a re-routed stream's record spans replicas seamlessly.
    # --- per-request SLOs (stamped by the traffic generator; 0 = none) ---
    slo_ttft_s: float = 0.0       # TTFT target this request is judged by
    deadline_s: float = 0.0       # admission deadline: the router sheds the
                                  # request if not dispatched within this
                                  # many seconds of arrival
    prefix_id: int = -1           # shared-prefix pool tag from the traffic
                                  # generator (-1 = unique prompt): benches
                                  # split warm vs cold TTFT on it


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    eos_id: int = -1              # -1: only stop at max_new_tokens
    crest_enabled: bool = False
    crest_every: int = 4          # run a BIST probe wave every N engine steps
    crest_cfg: crest.CrestConfig = dataclasses.field(default_factory=crest.CrestConfig)
    batched: bool = True          # one jitted decode over the whole slot grid
    prefill_chunk: int = 32       # chunked-prefill piece size (0 = whole prompt;
                                  # clamped to the ring length for windowed archs)
    token_budget: int = 0         # max prompt tokens admitted per step (0 = no cap;
                                  # enforced at chunk granularity)
    temperature: float = 0.0      # <= 0: greedy argmax (the deterministic
                                  # test path); > 0: seeded sampling
    top_k: int = 0                # restrict sampling to the k best logits (0 = all)
    sample_seed: int = 0          # sampling is deterministic given seed + call order
    draft_len: int = 0            # speculative decode: K drafted tokens per slot
                                  # per step (0 = plain one-token decode; clamped
                                  # to window-1 for ring-buffer archs). Greedy
                                  # uses argmax-prefix acceptance; temperature
                                  # > 0 uses speculative SAMPLING (rejection
                                  # resampling — distribution-exact with plain
                                  # sampled decode)
    ngram_max: int = 3            # longest suffix n-gram the prompt-lookup
                                  # drafter tries to match (see serve/spec.py)
    ngram_lookback: int = 512     # drafter scans at most this many trailing
                                  # context tokens (bounds per-step host work)
    tp_policy: str = "cascade"    # param placement when a mesh is passed:
                                  # 'cascade' (column-parallel, zero partial-
                                  # sum all-reduce) or 'megatron' (row+column
                                  # baseline with the classic all-reduce)
    fused: bool = False           # route decode/extend/verify through the
                                  # Pallas kernels (packed-FP4 matmul +
                                  # decode attention). Needs serve_fp4
                                  # params and the batched path; interpret
                                  # mode keeps it runnable (and token-exact
                                  # vs the jnp path) on CPU
    paged: bool = False           # page-granular KV pool: cache leaves are
                                  # (num_pages, page_size, ...) with host-
                                  # owned per-slot block tables; token-exact
                                  # with the dense cache. Full-attention
                                  # archs only (ring/recurrent downgrade
                                  # with a warning); incompatible with mesh
    page_size: int = 16           # tokens per physical page
    num_pages: int = 0            # pool size (0 = auto: max_batch *
                                  # blocks_per_slot + 1 — enough that every
                                  # slot can always fill, plus the trash page)
    prefix_cache: bool = False    # radix-tree prefix cache over token-id
                                  # prompts: admission maps shared prefixes
                                  # to resident pages instead of
                                  # re-prefilling them (implies paged)
    evict_watermark: float = 0.9  # pool-pressure fraction above which LRU
                                  # tree-only pages are evicted at alloc time


@dataclasses.dataclass
class _Staging:
    """A request mid-prefill: holds its batch-1 cache until fully prefilled."""
    req: Request
    cache: Any
    consumed: int
    slot: int


class ServeEngine:
    def __init__(self, model, params, ccfg: CascadeConfig, scfg: ServeConfig,
                 mesh=None, clock=None):
        self.model = model
        self.params = params
        self.ccfg = ccfg
        self.scfg = scfg
        self.mesh = mesh
        # every serving-path latency number (request timestamps, admission
        # waits, step times) reads THIS clock — wall time by default, a
        # shared VirtualClock in deterministic traffic tests (the harness
        # advances it; the engine only reads it)
        self.clock = clock if clock is not None else MonotonicClock()
        self.tp_policy = scfg.tp_policy
        # the cascade policy installs the activation-broadcast discipline
        # (constrain_* hooks in model code); megatron is the measured GSPMD
        # baseline — no constraints, the partitioner emits its all-reduces
        self._act_policy = "cascade" if scfg.tp_policy == "cascade" else "none"
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * scfg.max_batch
        self.crest_state = None
        self.fault_mask = None          # set by tests/demos to inject faults
        self._probe_w = None
        self._steps = 0
        self.step_times: list = []
        self._decode_tokens = 0
        self._admission_waits: list = []
        self._retired: List[Request] = []
        self._rejected = 0
        self._staging: Optional[_Staging] = None
        # Sampled serving draws random bits INSIDE sharded jitted steps (the
        # fused sampled decode step and the speculative verify+accept step).
        # With the legacy non-partitionable threefry lowering, GSPMD
        # generates DIFFERENT bits when it partitions a generation over the
        # mesh, so a sharded engine's draws would silently diverge from the
        # unsharded engine's — distribution-preserving but realization-
        # breaking (irreproducible across mesh shapes). The partitionable
        # implementation is sharding-invariant by contract and the default
        # in newer jax; opt in for the older pinned versions. Scoped to
        # sampled-engine construction (greedy engines never draw), BEFORE
        # the key below is made, so unrelated code that merely imports this
        # module keeps its RNG streams. It is still a PROCESS-GLOBAL jax
        # flag — every sampled engine must share it (that is what the
        # sharded-vs-unsharded parity contract requires), and any unrelated
        # jax.random use in the same process re-bases its realizations too,
        # so the flip is announced once instead of happening silently.
        if (scfg.temperature > 0.0
                and not jax.config.jax_threefry_partitionable):
            warnings.warn(
                "sampled serving enables jax_threefry_partitionable "
                "(process-global): jax.random realizations drawn after this "
                "point differ from the legacy lowering's; distributions and "
                "seed-determinism are unaffected", RuntimeWarning,
                stacklevel=3)
            # the designated site: sampled engines REQUIRE one shared
            # process-global threefry lowering (sharded-vs-unsharded
            # parity), and the flip warns just above
            # lint: allow[jax-config-global] — designated global-config site
            jax.config.update("jax_threefry_partitionable", True)
        # ONE on-device RNG discipline for every sampling site (batched grid,
        # admission, slot-wise loop): draw i uses fold_in(PRNGKey(seed), i),
        # so all modes are deterministic given seed + draw order and no
        # logits row is ever copied to host just to sample it
        self._sample_key = jax.random.PRNGKey(scfg.sample_seed)
        self._sample_step = 0
        if scfg.temperature > 0.0:
            self._pick_fn = jax.jit(
                lambda row, key: _sample_tokens(row[None, :], key,
                                                scfg.temperature, scfg.top_k)[0])
        self._accepted_drafts = 0     # drafted tokens the verify pass accepted
        self._spec_slot_steps = 0     # (slot, step) pairs that ran speculation
        # per-slot draft context, appended incrementally as tokens commit
        # (rebuilding prompt+emitted every step would be O(stream^2) host work)
        self._spec_ctx: List[Optional[list]] = [None] * scfg.max_batch

        # Silent mode downgrades are a bug class of their own (a bench that
        # thinks it measured speculation but ran plain decode): every
        # downgrade warns ONCE (at construction) and is recorded so
        # metrics()['effective_mode'] exposes the path that actually runs.
        self.downgrades: List[str] = []

        def _downgrade(msg: str):
            self.downgrades.append(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=3)

        # batched mode needs the stacked-cache API and flat logits
        # (multi-codebook heads only work slot-wise for now); every other
        # registry family — full/windowed attention, MLA, recurrent — runs
        # the batched fast path over its own stacked state
        window = getattr(getattr(model, "attn_cfg", None), "window", 0)
        codebooks = getattr(getattr(model, "cfg", None), "n_codebooks", 0)
        self.batched = (scfg.batched and not codebooks
                        and all(hasattr(model, m) for m in _BATCHED_API))
        if scfg.batched and not self.batched:
            _downgrade(
                "batched serving requested but this model "
                + ("has a multi-codebook head" if codebooks
                   else "lacks the stacked-cache API")
                + " — falling back to the slot-wise decode loop")
        # windowed/recurrent archs hold O(window)/O(1) state: prompt length
        # is not bounded by the cache, and there is no context-limit retire
        self.ctx_unbounded = bool(getattr(model, "unbounded_context", False))
        kv_dtype = ccfg.resolved_kv_dtype
        # speculative decode: batched-only, needs the verify/rewind API, and
        # the (1+K) verify chunk must fit inside a ring buffer just like a
        # prefill chunk. Greedy AND sampled serving both speculate — sampled
        # acceptance runs rejection resampling (spec_sample_accept)
        self._draft_len = 0
        if scfg.draft_len > 0:
            if not self.batched:
                _downgrade("draft_len > 0 requested but speculation needs "
                           "the batched stacked-cache path — speculative "
                           "decode disabled")
            elif not all(hasattr(model, m) for m in _SPEC_API):
                _downgrade("draft_len > 0 requested but this model lacks "
                           "spec_verify/spec_rewind — speculative decode "
                           "disabled")
            else:
                self._draft_len = (min(scfg.draft_len, window - 1) if window
                                   else scfg.draft_len)
        self.spec = self._draft_len > 0
        self._sampled = scfg.temperature > 0.0
        if mesh is not None and not self.batched:
            raise ValueError(
                "mesh serving requires the batched stacked-cache path "
                "(batched=True and a model exposing write_cache/prefill_extend)")
        # fused decode: flip use_kernel ON in the ccfg the jitted closures
        # below capture, so decode, chunked prefill-extend and spec verify
        # all route linears through the packed-FP4 Pallas matmul (and
        # single-token decode attention through the decode kernel). The
        # weights stay packed codes+scales end-to-end — dequantization
        # happens per-tile inside the kernel, never as a materialized dense
        # tree. Downgrades (don't crash, record + warn) when the
        # prerequisites are missing.
        self.fused = False
        if scfg.fused:
            if ccfg.mode != "serve_fp4":
                _downgrade(
                    f"fused decode requested but ccfg.mode={ccfg.mode!r} — "
                    "the FP4 kernel path needs packed serve_fp4 params "
                    "(codes+scales); running the jnp path")
            elif not self.batched:
                _downgrade(
                    "fused decode requested but the engine runs the "
                    "slot-wise loop — fused dispatch needs the batched "
                    "stacked-cache path; running the jnp path")
            elif mesh is not None:
                _downgrade(
                    "fused decode requested with a device mesh — Pallas "
                    "calls inside GSPMD-partitioned steps are unsupported; "
                    "running the jnp path")
            else:
                self.fused = True
                ccfg = dataclasses.replace(ccfg, use_kernel=True)
                self.ccfg = ccfg
        # paged KV: one fixed-shape page pool per cache leaf, host-owned
        # per-slot block tables, gather-based dense views inside the jitted
        # steps (token-exact with the dense cache). Prefix caching rides on
        # top (the radix tree maps shared prompt prefixes to resident pages).
        self.paged = False
        self.prefix: Optional[RadixPrefixCache] = None
        self.pool: Optional[PagePool] = None
        self._prefix_hits = 0
        self._prefix_lookups = 0
        if scfg.paged or scfg.prefix_cache:
            if not self.batched:
                _downgrade(
                    "paged KV requested but the engine runs the slot-wise "
                    "loop — paging needs the batched stacked-cache path; "
                    "running the dense cache")
            elif mesh is not None:
                _downgrade(
                    "paged KV requested with a device mesh — pool leaves "
                    "have no slot axis to shard over data; running the "
                    "dense cache")
            elif not (getattr(model, "paged_attention", False)
                      and hasattr(model, "init_paged_cache")):
                _downgrade(
                    "paged KV requested but this model's cache state has no "
                    "page-granular layout (ring-buffer / recurrent / "
                    "multi-codebook state) — running the dense cache")
            else:
                self.paged = True
        if self.batched:
            # round the cache length up to a chunk multiple so padded chunk
            # writes never clamp into (and clobber) valid cache entries; a
            # verify pass writes up to draft_len rows past a stream's last
            # position, so speculation adds that much headroom
            c = scfg.prefill_chunk
            need = scfg.max_len + self._draft_len
            self._cache_len = (-(-need // c) * c) if c > 0 else need
            # ring buffers hold exactly the window; a prefill chunk must fit
            # inside the ring so within-chunk writes never collide (see
            # layers.attn_apply)
            self._chunk_cap = window
            if self.paged:
                ps = max(1, scfg.page_size)
                self._page_size = ps
                nb = -(-self._cache_len // ps)
                self._blocks_per_slot = nb
                self._cache_len = nb * ps
                # default pool: every slot can fill all its blocks, plus the
                # reserved trash page — admission can then never deadlock
                # (tree-only pages are always evictable, see serve/prefix.py)
                npages = scfg.num_pages or scfg.max_batch * nb + 1
                self.cache = model.init_paged_cache(scfg.max_batch, npages,
                                                    ps, dtype=kv_dtype)
                self.pool = PagePool(npages)
                self._bt = np.zeros((scfg.max_batch, nb), np.int32)
                self._slot_pages: List[List[int]] = [
                    [] for _ in range(scfg.max_batch)]
                self._watermark_pages = max(
                    1, int(scfg.evict_watermark * (npages - 1)))
                self._copy_fn = jax.jit(
                    lambda c_, s_, d_: model.paged_copy_page(c_, s_, d_),
                    donate_argnums=(0,))
                # a fresh lambda per engine (like _copy_fn above): jitting
                # the bare module-level function would share one tracing
                # cache across engines, breaking per-engine donation and
                # the retrace guard's compile accounting
                self._reset_pos_fn = jax.jit(
                    lambda c_, s_, p_: reset_slot_pos(c_, s_, p_),
                    donate_argnums=(0,))
                if scfg.prefix_cache:
                    self.prefix = RadixPrefixCache(self.pool, ps,
                                                   copy_page=self._cow_page)
            else:
                self.cache = model.init_cache(scfg.max_batch, self._cache_len,
                                              dtype=kv_dtype)
            self.caches: List[Any] = []   # unused in batched mode
            if mesh is not None:
                # data parallelism only when the slot grid divides the data
                # axes: otherwise activations stay batch-replicated to match
                # the (necessarily replicated) cache — unevenly batch-sharded
                # k/v written into a replicated cache would lower to exactly
                # the masked-add all-reduces the cascade policy forbids
                dsize = 1
                for a in ("pod", "data"):
                    dsize *= mesh.shape.get(a, 1)
                self._batch_axes = (("pod", "data")
                                    if dsize > 1 and scfg.max_batch % dsize == 0
                                    else ())
                # params placed by the TP policy (tied-embedding archs keep
                # a replicated table so the tied head never contracts over a
                # sharded dim); stacked cache shards its slot axis over data
                tied = bool(getattr(getattr(model, "cfg", None),
                                    "tie_embeddings", False))
                pspecs = shd.filter_divisible(
                    shd.param_specs(params, scfg.tp_policy, tied_embed=tied),
                    params, mesh)
                self.params = jax.device_put(params, shd.named_shardings(mesh, pspecs))
                self._cache_pspecs = model.cache_pspecs(self.cache, mesh)
                self.cache = jax.device_put(
                    self.cache, shd.named_shardings(mesh, self._cache_pspecs))
                # pin cache outputs inside every jitted step so the slot-axis
                # placement survives donation round-trips (GSPMD propagation
                # alone is not guaranteed to hand the sharding back)
                pin = lambda c_: jax.tree.map(
                    lambda x, s: jax.lax.with_sharding_constraint(x, s),
                    c_, self._cache_pspecs)
            else:
                pin = lambda c_: c_
            self._pin = pin

            # paged steps take the host-owned block table as one extra
            # device arg ({"tokens", "block_table"} batch dict); the jitted
            # computations are otherwise identical — the model gathers dense
            # per-slot K/V views through the table, so shapes stay fixed
            if self.paged:
                def _decode_step(p, t, c_, bt):
                    logits, c2 = model.decode_step(
                        p, {"tokens": t, "block_table": bt}, c_, ccfg)
                    return logits, pin(c2)

                self._decode_fn = jax.jit(_decode_step, donate_argnums=(2,))
                self._extend_fn = jax.jit(
                    lambda p, t, c_, bt, n: model.prefill_extend(
                        p, {"tokens": t, "block_table": bt}, c_, ccfg,
                        n_valid=n),
                    donate_argnums=(2,))
            else:
                def _decode_step(p, t, c_):
                    logits, c2 = model.decode_step(p, {"tokens": t}, c_, ccfg)
                    return logits, pin(c2)

                self._decode_fn = jax.jit(_decode_step, donate_argnums=(2,))
                self._extend_fn = jax.jit(
                    lambda p, t, c_, n: model.prefill_extend(p, {"tokens": t}, c_, ccfg,
                                                             n_valid=n),
                    donate_argnums=(2,))
                self._write_fn = jax.jit(
                    lambda c_, s_, i: pin(model.write_cache(c_, s_, i)),
                    donate_argnums=(0,))
            if self.spec:
                if self.paged:
                    def _verify_step(p, t, c_, bt):
                        logits, c2, ckpt = model.spec_verify(
                            p, {"tokens": t, "block_table": bt}, c_, ccfg)
                        return logits, pin(c2), ckpt
                else:
                    def _verify_step(p, t, c_):
                        logits, c2, ckpt = model.spec_verify(p, {"tokens": t}, c_, ccfg)
                        return logits, pin(c2), ckpt

                self._verify_fn = jax.jit(_verify_step, donate_argnums=(2,))
                # donate only the cache: checkpoint leaves have chunk-sized
                # shapes no output can reuse (donating them just warns).
                # Paged checkpoints carry their block table, so the rewind
                # signature is mode-independent.
                self._rewind_fn = jax.jit(
                    lambda c_, ck, keep: pin(model.spec_rewind(c_, ck, keep)),
                    donate_argnums=(0,))
                if self._sampled:
                    # speculative SAMPLING: verify + accept/resample fused
                    # in one jitted dispatch — the acceptance uniforms, the
                    # residual resample and the bonus draw all stay on
                    # device, derived from the step's single fold_in key
                    if self.paged:
                        def _spec_sampled_step(p, t, c_, bt, keff, key):
                            logits, c2, ckpt = model.spec_verify(
                                p, {"tokens": t, "block_table": bt}, c_, ccfg)
                            a, tok = spec_sample_accept(
                                logits, t[:, 1:], keff, key,
                                scfg.temperature, scfg.top_k)
                            return a, tok, pin(c2), ckpt
                    else:
                        def _spec_sampled_step(p, t, c_, keff, key):
                            logits, c2, ckpt = model.spec_verify(
                                p, {"tokens": t}, c_, ccfg)
                            a, tok = spec_sample_accept(
                                logits, t[:, 1:], keff, key,
                                scfg.temperature, scfg.top_k)
                            return a, tok, pin(c2), ckpt

                    self._spec_sample_fn = jax.jit(_spec_sampled_step,
                                                   donate_argnums=(2,))
            if scfg.temperature > 0.0:
                # on-device sampling for the batched grid: decode + categorical
                # draw fused in one jitted step (no per-step host vocab copy)
                if self.paged:
                    def _sampled_step(p, t, c_, bt, key):
                        logits, c2 = model.decode_step(
                            p, {"tokens": t, "block_table": bt}, c_, ccfg)
                        return _sample_tokens(logits[:, -1], key,
                                              scfg.temperature,
                                              scfg.top_k), pin(c2)
                else:
                    def _sampled_step(p, t, c_, key):
                        logits, c2 = model.decode_step(p, {"tokens": t}, c_, ccfg)
                        return _sample_tokens(logits[:, -1], key, scfg.temperature,
                                              scfg.top_k), pin(c2)
                self._sample_fn = jax.jit(_sampled_step, donate_argnums=(2,))
        else:
            self._cache_len = scfg.max_len
            self._chunk_cap = 0
            self.cache = None
            self.caches = [None] * scfg.max_batch
            self._decode_fn = jax.jit(
                lambda p, t, c_: model.decode_step(p, {"tokens": t}, c_, ccfg))
        if scfg.crest_enabled:
            self._probe_w = self._dense_head_weight()
            self.crest_state = crest.crest_init(self._probe_w.shape[1], scfg.crest_cfg)

    def _dense_head_weight(self):
        """Dense view of the lm_head weight used for CREST BIST probes
        (paper Section 20.6: CREST doubles as POST/BIST with test vectors)."""
        from repro.core import cascade as C
        head = self.params.get("lm_head")
        if head is None:
            return None
        return C.linear_weight(head, self.ccfg)

    # ------------------------------------------------------------ admission
    def submit(self, req: Request):
        # an open-loop driver (or a failover rebuild) pre-stamps the arrival
        # time; only a fresh direct submit takes "now" — re-stamping would
        # erase queueing delay already accrued (on a dead replica, or in a
        # router queue), which is exactly the delay TTFT must charge
        if req.created_at == 0.0:
            req.created_at = self.clock.now()
        self.queue.append(req)

    def _pop_admittable(self) -> Optional[Request]:
        """Next queued request; un-servable prompts — empty, or (for archs
        whose cache grows with context) too long for the slot grid to hold
        with room for even one generated token — are rejected, not crashed
        on / silently clobbered. Windowed/recurrent archs hold O(window)
        state, so no prompt is too long for them."""
        while self.queue:
            req = self.queue.popleft()
            if len(req.prompt) > 0 and (self.ctx_unbounded
                                        or len(req.prompt) < self.scfg.max_len):
                return req
            req.done = True
            req.finished_at = self.clock.now()
            self._rejected += 1
            self._retired.append(req)
        return None

    def _free_slot(self) -> Optional[int]:
        staged = self._staging.slot if self._staging is not None else -1
        for i in range(self.scfg.max_batch):
            if self.slots[i] is None and i != staged:
                return i
        return None

    # ------------------------------------------------------ page management
    def _bt_dev(self) -> jax.Array:
        """Device copy of the host block-table mirror (pushed every step —
        it is a few KB, and host-owned so allocation stays plain Python)."""
        return jnp.asarray(self._bt)

    def _alloc_page(self) -> int:
        """One page off the pool, enforcing the eviction watermark first.

        Under prefix caching, pool pressure above the watermark evicts LRU
        tree-only pages; a genuinely full pool force-evicts one more. With
        the default pool sizing this never raises (slots can always fill)."""
        if self.prefix is not None:
            self.prefix.maybe_evict(self._watermark_pages)
            if self.pool.free_pages == 0:
                self.prefix.evict(1)
        return self.pool.alloc()

    def _cow_page(self, src: int) -> Optional[int]:
        """Radix-cache COW hook: clone physical page ``src`` for a stream
        that diverges mid-page. No eviction here — the tree is mid-walk and
        the LRU victim could be ``src`` itself; a full pool just skips the
        partial-page match (colder, still correct)."""
        if self.pool.free_pages == 0:
            return None
        dst = self.pool.alloc()
        self.cache = self._copy_fn(self.cache, jnp.int32(src), jnp.int32(dst))
        return dst

    def _ensure_pages(self, slot: int, n_tokens: int):
        """Back the slot's first ``n_tokens`` rows with physical pages."""
        ps = self._page_size
        needed = min(-(-n_tokens // ps), self._blocks_per_slot)
        pages = self._slot_pages[slot]
        while len(pages) < needed:
            pg = self._alloc_page()
            self._bt[slot, len(pages)] = pg
            pages.append(pg)

    def _release_slot_pages(self, slot: int):
        """Drop the slot's page refs and point its table at the trash page
        (row 0) so any stale in-flight write/read for this slot is inert.
        Pages the radix tree still holds stay resident for future hits."""
        for pg in self._slot_pages[slot]:
            self.pool.release(pg)
        self._slot_pages[slot] = []
        self._bt[slot, :] = 0

    def _admit_paged(self):
        """Paged admission: prefill the unshared prompt suffix directly into
        the resident grid (no staging cache, no slot write).

        With the prefix cache on, the radix tree resolves the longest cached
        prefix first: matched pages go straight into the slot's block table
        (refcount bump — shared pages are past every write frontier, so
        they are read-only by construction), ``pos`` is reset to the matched
        length, and ONLY the unshared suffix is prefilled — and only the
        suffix is charged against ``token_budget``. The extend runs over
        the full grid with a per-slot ``n_valid`` vector (only the staging
        slot is nonzero); resident streams' rows land above their ``pos``
        (mask-invalid garbage, overwritten when those streams advance) or
        in the trash page, so their decode is untouched — admission stays
        token-exact with the dense engine's staging-cache path."""
        budget = self.scfg.token_budget or 1 << 30
        spent = 0
        while spent < budget:
            if self._staging is None:
                slot = self._free_slot()
                if slot is None:
                    return
                req = self._pop_admittable()
                if req is None:
                    return
                req.admitted_at = self.clock.now()
                self._admission_waits.append(req.admitted_at - req.created_at)
                matched = 0
                assert not self._slot_pages[slot]
                if self.prefix is not None:
                    m = self.prefix.match(req.prompt)
                    self._slot_pages[slot] = list(m.pages)
                    self._bt[slot, :len(m.pages)] = m.pages
                    matched = m.matched
                    self._prefix_hits += m.hit_full
                    self._prefix_lookups += len(req.prompt)
                self.cache = self._reset_pos_fn(self.cache, jnp.int32(slot),
                                                jnp.int32(matched))
                self._staging = _Staging(req, None, matched, slot)
            st = self._staging
            prompt = st.req.prompt
            chunk = self.scfg.prefill_chunk or len(prompt)
            logits = None
            while st.consumed < len(prompt) and spent < budget:
                n = min(chunk, len(prompt) - st.consumed)
                self._ensure_pages(st.slot, st.consumed + n)
                toks = np.zeros((self.scfg.max_batch, chunk), np.int32)
                toks[st.slot, :n] = prompt[st.consumed:st.consumed + n]
                nv = np.zeros((self.scfg.max_batch,), np.int32)
                nv[st.slot] = n
                logits, self.cache = self._extend_fn(
                    self.params, jnp.asarray(toks), self.cache,
                    self._bt_dev(), jnp.asarray(nv))
                st.consumed += n
                spent += n                  # unshared suffix only
            if st.consumed < len(prompt):
                return                      # budget exhausted mid-prompt
            nxt = self._pick(logits[st.slot, -1])
            self._commit_token(st.req, nxt)
            self.slots[st.slot] = st.req
            if self.prefix is not None:
                # publish the freshly prefilled full pages for future hits
                self.prefix.insert(prompt, self._slot_pages[st.slot])
            if self.spec:
                self._spec_ctx[st.slot] = (
                    st.req.prompt.tolist()
                    + st.req.tokens_out[st.req.prompt_carried:])
            self._staging = None
            self._retire_if_done(st.req, st.slot, nxt)

    def _admit_batched(self):
        """Spend up to ``token_budget`` prompt tokens on (chunked) prefill."""
        budget = self.scfg.token_budget or 1 << 30
        spent = 0
        while spent < budget:
            if self._staging is None:
                slot = self._free_slot()
                if slot is None:
                    return
                req = self._pop_admittable()
                if req is None:
                    return
                req.admitted_at = self.clock.now()
                self._admission_waits.append(req.admitted_at - req.created_at)
                sub = self.model.init_cache(1, self._cache_len,
                                            dtype=self.ccfg.resolved_kv_dtype)
                self._staging = _Staging(req, sub, 0, slot)
            st = self._staging
            prompt = st.req.prompt
            chunk = self.scfg.prefill_chunk or len(prompt)
            if self._chunk_cap:
                chunk = min(chunk, self._chunk_cap)
            logits = None
            while st.consumed < len(prompt) and spent < budget:
                n = min(chunk, len(prompt) - st.consumed)
                toks = np.zeros((1, chunk), np.int32)
                toks[0, :n] = prompt[st.consumed:st.consumed + n]
                logits, st.cache = self._extend_fn(
                    self.params, jnp.asarray(toks), st.cache, jnp.int32(n))
                st.consumed += n
                spent += n
            if st.consumed < len(prompt):
                return                      # budget exhausted mid-prompt
            nxt = self._pick(logits[0, -1])
            self._commit_token(st.req, nxt)
            self.cache = self._write_fn(self.cache, st.cache, jnp.int32(st.slot))
            self.slots[st.slot] = st.req
            if self.spec:
                # seed the slot's draft context with the visible stream
                # (prompt already contains failover-carried tokens)
                self._spec_ctx[st.slot] = (
                    st.req.prompt.tolist()
                    + st.req.tokens_out[st.req.prompt_carried:])
            self._staging = None
            # the prefill-generated token may already end the stream
            self._retire_if_done(st.req, st.slot, nxt)

    def _admit_slotwise(self):
        for i in range(self.scfg.max_batch):
            if self.slots[i] is None and self.queue:
                req = self._pop_admittable()
                if req is None:
                    return
                req.admitted_at = self.clock.now()
                self._admission_waits.append(req.admitted_at - req.created_at)
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, cache = self.model.prefill(
                    self.params, {"tokens": toks}, self.ccfg, max_len=self.scfg.max_len)
                nxt = self._pick(logits[0, -1])
                self._commit_token(req, nxt)
                self.slots[i] = req
                self.caches[i] = cache
                # the prefill-generated token may already end the stream
                self._retire_if_done(req, i, nxt)

    def _admit(self):
        if self.paged:
            self._admit_paged()
        elif self.batched:
            self._admit_batched()
        else:
            self._admit_slotwise()

    # --------------------------------------------------------------- decode
    def _active(self):
        return [i for i, r in enumerate(self.slots) if r is not None]

    def _next_sample_key(self):
        """One counter for every sampling site: fold_in(seed, draw index)."""
        key = jax.random.fold_in(self._sample_key, self._sample_step)
        self._sample_step += 1
        return key

    def _pick(self, row) -> int:
        """Next token from a (V,) logits row (admission / slot-wise path).
        Both argmax and sampling stay ON DEVICE — the admission and
        slot-wise modes share the batched grid's seeded-categorical RNG
        discipline (same fold_in counter), so no path ever copies a vocab
        row to host or keeps a second host-side RNG stream."""
        if self.scfg.temperature <= 0.0:
            return int(jnp.argmax(row))
        return int(self._pick_fn(jnp.asarray(row), self._next_sample_key()))

    def _commit_token(self, req: Request, tok: int):
        """Append a committed token WITH its telemetry: the clock reading at
        commit time and — on the stream's first token — ``first_token_at``.
        A failover clone arrives with carried tokens/timestamps, so the
        first-token stamp only ever fires once per client-visible stream
        (the dead replica's TTFT is the stream's TTFT)."""
        req.tokens_out.append(tok)
        req.token_times.append(self.clock.now())
        if req.first_token_at == 0.0:
            req.first_token_at = req.token_times[-1]

    def _retire_if_done(self, req: Request, i: int, nxt: int):
        # cache usage: prompt + tokens emitted since (carried ones are
        # already inside the prompt — failover clones)
        used = len(req.prompt) + len(req.tokens_out) - req.prompt_carried
        if (len(req.tokens_out) >= req.max_new_tokens
                or nxt == self.scfg.eos_id
                # context limit: the next write would fall outside the cache
                # (never fires for windowed/recurrent archs — ring buffers
                # wrap and recurrent state is O(1))
                or (not self.ctx_unbounded and used >= self.scfg.max_len)):
            req.done = True
            req.finished_at = self.clock.now()
            self._retired.append(req)
            self.slots[i] = None
            if self.paged:
                self._release_slot_pages(i)
            if not self.batched:
                self.caches[i] = None

    def _decode_batched(self, active: List[int]) -> int:
        toks = np.zeros((self.scfg.max_batch, 1), np.int32)
        for i in active:
            req = self.slots[i]
            toks[i, 0] = req.tokens_out[-1]
            if self.paged:
                # the pending token writes at row used-1; back it with a page
                self._ensure_pages(i, len(req.prompt) + len(req.tokens_out)
                                   - req.prompt_carried)
        bt = (self._bt_dev(),) if self.paged else ()
        if self.scfg.temperature <= 0.0:
            logits, self.cache = self._decode_fn(self.params, jnp.asarray(toks),
                                                 self.cache, *bt)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        else:
            # on-device sampling: one fused decode+categorical dispatch; the
            # per-row Gumbel noise is positional (a function of key + slot
            # index), so active rows never depend on garbage-slot contents
            sampled, self.cache = self._sample_fn(self.params, jnp.asarray(toks),
                                                  self.cache, *bt,
                                                  self._next_sample_key())
            nxt = np.asarray(sampled)
        produced = 0
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            self._commit_token(req, tok)
            produced += 1
            self._retire_if_done(req, i, tok)
        return produced

    def _decode_spec(self, active: List[int]) -> int:
        """One speculative engine step: draft up to K tokens per slot
        (prompt lookup over the slot's own stream, reporting the per-slot
        effective draft length ``k_eff``), score all K+1 positions in ONE
        batched verify pass, commit the accepted prefix plus a final
        bonus/resampled token, then rewind each slot's cache to its accept
        boundary.

        Greedy (``temperature <= 0``): accept the longest real-draft prefix
        matching the model's own argmax — token-exact with plain greedy
        decode. Sampled: the fused verify+accept step runs rejection
        resampling on device (``spec_sample_accept``) — every committed
        token is distributed exactly as plain sampled decode. Padded
        proposals (positions >= ``k_eff``) are never scored as real in
        either mode: under sampling a padded token was never drawn from q
        (scoring it would corrupt the acceptance law), and under greedy a
        padded 0 could spuriously match a legitimate argmax-0 token."""
        k = self._draft_len
        toks = np.zeros((self.scfg.max_batch, k + 1), np.int32)
        keff = np.zeros(self.scfg.max_batch, np.int32)
        for i in active:
            # the draft context is the slot's visible stream (prompt — which
            # already contains failover-carried tokens — plus every token
            # emitted since), maintained incrementally; the drafter scans at
            # most the trailing ``ngram_lookback`` tokens of it
            ctx = self._spec_ctx[i]
            toks[i, 0] = ctx[-1]               # == tokens_out[-1], pending
            toks[i, 1:], keff[i] = ngram_propose(
                np.asarray(ctx[-self.scfg.ngram_lookback:], np.int32),
                k, self.scfg.ngram_max)
            if self.paged:
                # the verify chunk writes rows used-1 .. used-1+K; rows past
                # the slot's capacity land in the trash page, matching the
                # dense path's headroom semantics
                req = self.slots[i]
                self._ensure_pages(i, len(req.prompt) + len(req.tokens_out)
                                   - req.prompt_carried + k)
        bt = (self._bt_dev(),) if self.paged else ()
        if self._sampled:
            # ONE counter draw per engine step (the plain sampled step's
            # discipline); accept/resample/bonus randomness derives from it
            a_dev, fin_dev, self.cache, ckpt = self._spec_sample_fn(
                self.params, jnp.asarray(toks), self.cache, *bt,
                jnp.asarray(keff), self._next_sample_key())
            acc = np.asarray(a_dev)
            fin = np.asarray(fin_dev)
        else:
            logits, self.cache, ckpt = self._verify_fn(
                self.params, jnp.asarray(toks), self.cache, *bt)
            greedy = np.asarray(jnp.argmax(logits, axis=-1))  # (B, K+1)
        keep = np.zeros(self.scfg.max_batch, np.int32)
        produced = 0
        for i in active:
            req = self.slots[i]
            if self._sampled:
                a = int(acc[i])
                # accepted drafts verbatim, then the resampled/bonus token
                seq = [int(toks[i, j + 1]) for j in range(a)] + [int(fin[i])]
            else:
                a = 0
                while a < keff[i] and greedy[i, a] == toks[i, a + 1]:
                    a += 1
                seq = [int(greedy[i, j]) for j in range(a + 1)]
            keep[i] = a + 1                     # accepted drafts + pending token
            self._spec_slot_steps += 1
            # commit one token at a time so eos / max_new / context-limit
            # retirement fires at EXACTLY the token where plain decode
            # would have stopped
            delivered = 0
            ctx = self._spec_ctx[i]
            for tok in seq:
                self._commit_token(req, tok)
                ctx.append(tok)
                delivered += 1
                self._retire_if_done(req, i, tok)
                if req.done:
                    break
            # acceptance counts only DELIVERED drafts (retirement may truncate
            # mid-acceptance), keeping tokens/step/slot = accepted_per_step + 1
            self._accepted_drafts += delivered - 1
            produced += delivered
            lb = self.scfg.ngram_lookback
            if len(ctx) > 2 * lb:               # drafter only reads the tail
                del ctx[:len(ctx) - lb]
        # roll every slot back to its accept boundary (inactive slots:
        # keep=0 — a full rewind, restoring the pre-verify cache)
        self.cache = self._rewind_fn(self.cache, ckpt, jnp.asarray(keep))
        return produced

    def _decode_slotwise(self, active: List[int]) -> int:
        produced = 0
        for i in active:
            req = self.slots[i]
            tok = jnp.asarray([[req.tokens_out[-1]]], jnp.int32)
            logits, self.caches[i] = self._decode_fn(self.params, tok, self.caches[i])
            nxt = self._pick(logits[0, -1] if logits.ndim == 3 else logits[0, -1, 0])
            self._commit_token(req, nxt)
            produced += 1
            self._retire_if_done(req, i, nxt)
        return produced

    @contextlib.contextmanager
    def _sharded_scope(self):
        """Mesh + activation-policy scope for every on-device call.

        Jit tracing happens lazily at first dispatch, so the mesh context
        (bare-``PartitionSpec`` constraints need it) and the activation
        policy (the ``constrain_*`` hooks inside model code) must surround
        the CALLS, not the ``jax.jit`` constructions. The policy is cleared
        on exit so an unsharded engine in the same process — a failover
        survivor, the slot-wise parity baseline — never traces under a
        leftover mesh discipline.
        """
        if self.mesh is None:
            yield
            return
        shd.set_activation_policy(self.mesh, self._act_policy,
                                  batch_axes=self._batch_axes)
        try:
            with self.mesh:
                yield
        finally:
            shd.clear_activation_policy()

    def step(self) -> int:
        """One engine step; returns number of decode tokens produced."""
        with self._sharded_scope():
            self._admit()
            active = self._active()
            if not active:
                return 0
            t0 = self.clock.now()
            self._steps += 1
            if self.scfg.crest_enabled and self._steps % self.scfg.crest_every == 0:
                self._crest_probe()
            produced = (self._decode_spec(active) if self.spec
                        else self._decode_batched(active) if self.batched
                        else self._decode_slotwise(active))
            self.step_times.append(self.clock.now() - t0)
            self._decode_tokens += produced
            return produced

    # ----------------------------------------------------- contract auditor
    def step_closures(self) -> Dict[str, dict]:
        """Every jitted step closure this engine constructed, by name:
        ``{"fn", "donates_cache", "takes_params"}`` — the registry
        ``repro.analysis.contract.audit_engine`` lowers and audits, and
        ``analysis.retrace`` reads compile counts from. Built on demand
        (by ``getattr`` over the mode-dependent attributes) so it is
        always in sync with what ``__init__`` actually constructed."""
        assert self.batched, "step closures exist only on the batched engine"
        reg: Dict[str, dict] = {}

        def _add(name, attr, donates_cache=True, takes_params=True):
            fn = getattr(self, attr, None)
            if fn is not None:
                reg[name] = {"fn": fn, "donates_cache": donates_cache,
                             "takes_params": takes_params}

        _add("decode", "_decode_fn")
        _add("extend", "_extend_fn")
        _add("write", "_write_fn", takes_params=False)
        _add("verify", "_verify_fn")
        _add("rewind", "_rewind_fn", takes_params=False)
        _add("spec_sample", "_spec_sample_fn")
        _add("sample", "_sample_fn")
        _add("copy_page", "_copy_fn", takes_params=False)
        _add("reset_pos", "_reset_pos_fn", takes_params=False)
        if getattr(getattr(self.model, "cfg", None), "vocab", 0):
            _add("pick", "_pick_fn", donates_cache=False, takes_params=False)
        return reg

    def _step_example_args(self, name: str) -> tuple:
        """Arguments shaped exactly like what ``step()`` dispatches for one
        named closure, for AOT lowering. Token grids are zeros (values are
        irrelevant to the lowered program); the live params/cache carry
        their real placement, so a mesh engine lowers the real sharded
        step. The rewind checkpoint comes from ``eval_shape`` over the
        verify closure — shape-faithful without running a verify pass
        (which would consume the donated cache)."""
        B = self.scfg.max_batch
        bt = (self._bt_dev(),) if self.paged else ()
        key = jax.random.fold_in(self._sample_key, 0)
        if name == "decode":
            return (self.params, jnp.zeros((B, 1), jnp.int32),
                    self.cache) + bt
        if name == "sample":
            return (self.params, jnp.zeros((B, 1), jnp.int32),
                    self.cache) + bt + (key,)
        if name in ("verify", "spec_sample"):
            toks = jnp.zeros((B, self._draft_len + 1), jnp.int32)
            if name == "verify":
                return (self.params, toks, self.cache) + bt
            return (self.params, toks, self.cache) + bt \
                + (jnp.zeros((B,), jnp.int32), key)
        if name == "extend":
            chunk = self.scfg.prefill_chunk or self.scfg.max_len
            if self._chunk_cap:
                chunk = min(chunk, self._chunk_cap)
            if self.paged:
                return (self.params, jnp.zeros((B, chunk), jnp.int32),
                        self.cache) + bt + (jnp.zeros((B,), jnp.int32),)
            staging = self.model.init_cache(
                1, self._cache_len, dtype=self.ccfg.resolved_kv_dtype)
            return (self.params, jnp.zeros((1, chunk), jnp.int32), staging,
                    jnp.int32(chunk))
        if name == "write":
            staging = self.model.init_cache(
                1, self._cache_len, dtype=self.ccfg.resolved_kv_dtype)
            return (self.cache, staging, jnp.int32(0))
        if name == "rewind":
            ckpt = jax.eval_shape(self._verify_fn,
                                  *self._step_example_args("verify"))[2]
            return (self.cache, ckpt, jnp.zeros((B,), jnp.int32))
        if name in ("copy_page", "reset_pos"):
            return (self.cache, jnp.int32(0), jnp.int32(0))
        if name == "pick":
            vocab = int(self.model.cfg.vocab)
            return (jnp.zeros((vocab,), jnp.float32), key)
        raise KeyError(f"unknown step closure {name!r}")

    def lower_step(self, name: str):
        """AOT lower + compile one step closure against the live params/
        cache placement; returns jax's compiled object (``.as_text()`` for
        the HLO). AOT compilation does not touch the jit dispatch cache,
        so auditing composes with the retrace guard."""
        entry = self.step_closures()[name]
        with self._sharded_scope():
            args = self._step_example_args(name)
            return entry["fn"].lower(*args).compile()

    def decode_step_hlo(self, which: str = "decode") -> str:
        """Compiled HLO of a batched serving step against the live params/
        cache placement — the executable form of the paper's interconnect
        claim: under ``tp_policy='cascade'`` this text contains zero
        partial-sum all-reduce (``benchmarks/hlo_analysis.
        partial_sum_allreduces``), under ``megatron`` it does not.

        ``which``: 'decode' (one-token step) or 'verify' (the speculative
        (1+K)-position verify pass; requires ``draft_len > 0``). With
        ``temperature > 0`` both forms lower the FUSED sampled computation
        the engine actually dispatches — the sampled decode step, and the
        sampled verify+accept/resample step (whose K+1 logit rows are
        pinned replicated before top-k/softmax/Gumbel, so speculative
        sampling obeys the same zero-partial-sum-AR invariant) — not the
        unused greedy ones.
        """
        assert self.batched, "decode_step_hlo requires the batched engine"
        if which == "verify":
            assert self.spec, "verify HLO requires draft_len > 0"
            return self.lower_step(
                "spec_sample" if self._sampled else "verify").as_text()
        return self.lower_step(
            "sample" if self._sampled else "decode").as_text()

    # ------------------------------------------------------------- failover
    def evict(self, i: int) -> Optional[Request]:
        """Free slot i and return its request. The stacked cache slot simply
        becomes garbage — decode state is reconstructible from the prompt +
        emitted tokens (idempotent regenerate), so nothing else to save."""
        req = self.slots[i]
        self.slots[i] = None
        if self.paged:
            self._release_slot_pages(i)
        if not self.batched:
            self.caches[i] = None
        return req

    def abort_in_flight(self) -> List[Request]:
        """Evict every resident/staging request (replica death path)."""
        out = [r for r in (self.evict(i) for i in self._active()) if r is not None]
        if self._staging is not None:
            if self.paged:
                self._release_slot_pages(self._staging.slot)
            out.append(self._staging.req)
            self._staging = None
        return out

    def busy(self) -> bool:
        return bool(self.queue) or self._staging is not None or bool(self._active())

    def load(self) -> int:
        """Queued + resident + mid-prefill work (dispatch balancing input)."""
        return (len(self.queue) + sum(r is not None for r in self.slots)
                + (self._staging is not None))

    # ---------------------------------------------------------------- crest
    def _crest_probe(self):
        """BIST probe wave (paper Section 20.6): run the CREST-protected
        matmul on the lm_head weight with pseudo-random test activations;
        detected faults accumulate in ``crest_state`` and are repaired via
        spare recomputation. ``fault_mask`` lets demos inject defects."""
        if self._probe_w is None:
            return
        key = jax.random.PRNGKey(self._steps)
        x = jax.random.normal(key, (4, self._probe_w.shape[0]), jnp.float32)
        _, self.crest_state = crest.crest_matmul(
            x, self._probe_w.astype(jnp.float32), self.crest_state,
            self.scfg.crest_cfg, self.fault_mask)

    def crest_report(self) -> dict:
        if self.crest_state is None:
            return {}
        return {"confirmed_faults": int(self.crest_state.confirmed_faults.sum()),
                "repaired": int(self.crest_state.n_repaired)}

    # -------------------------------------------------------------- metrics
    @property
    def effective_mode(self) -> str:
        """The decode path this engine ACTUALLY runs (downgrades included):
        '{spec|batched|slotwise}-{greedy|sampled}[-fused][-paged]'. Benches
        and tests assert on this instead of trusting the requested config."""
        decode = ("spec" if self.spec
                  else "batched" if self.batched else "slotwise")
        mode = f"{decode}-{'sampled' if self._sampled else 'greedy'}"
        if self.fused:
            mode += "-fused"
        if self.paged:
            mode += "-paged"
        return mode

    @staticmethod
    def latency_percentiles(requests) -> dict:
        """Per-request latency percentiles over finished requests.

        TTFT = first committed token minus ``created_at`` (the ARRIVAL
        time under an open-loop driver — queueing delay is charged here).
        Inter-token gaps are consecutive differences of each request's
        ``token_times``; under speculative decode a whole accepted run
        commits at one instant, so its intra-run gaps are honestly 0 and
        the step-boundary gap carries the verify-pass cost. Requests that
        never produced a token (rejected/shed) contribute nothing."""
        ttfts = [r.first_token_at - r.created_at for r in requests
                 if r.first_token_at > 0.0]
        gaps = [b - a for r in requests
                for a, b in zip(r.token_times, r.token_times[1:])]
        tt = np.asarray(ttfts, np.float64)
        gp = np.asarray(gaps, np.float64)
        return {
            "ttft_p50_s": float(np.percentile(tt, 50)) if tt.size else 0.0,
            "ttft_p99_s": float(np.percentile(tt, 99)) if tt.size else 0.0,
            "inter_token_p50_s": float(np.percentile(gp, 50)) if gp.size else 0.0,
            "inter_token_p99_s": float(np.percentile(gp, 99)) if gp.size else 0.0,
        }

    def metrics(self) -> dict:
        """Throughput/latency counters for the dashboard & benchmarks."""
        st = np.asarray(self.step_times, np.float64)
        total = float(st.sum()) if st.size else 0.0
        return {
            **self.latency_percentiles(self._retired),
            "batched": self.batched,
            "effective_mode": self.effective_mode,
            "downgrades": list(self.downgrades),
            "mesh": dict(self.mesh.shape) if self.mesh is not None else None,
            "tp_policy": self.tp_policy if self.mesh is not None else None,
            "spec": self.spec,
            "fused": self.fused,
            "paged": self.paged,
            "page_size": self._page_size if self.paged else 0,
            "prefix_cache": self.prefix is not None,
            "prefix_hits": self._prefix_hits,
            "prefix_lookups": self._prefix_lookups,
            # fraction of submitted prompt tokens served from resident pages
            "prefix_hit_rate": (self._prefix_hits / self._prefix_lookups
                                if self._prefix_lookups else 0.0),
            "pages_in_use": self.pool.pages_in_use if self.paged else 0,
            "pages_total": self.pool.num_pages - 1 if self.paged else 0,
            "evictions": self.prefix.evictions if self.prefix is not None else 0,
            "draft_len": self._draft_len,
            "draft_tokens_accepted": self._accepted_drafts,
            # mean drafted tokens accepted per (slot, step); +1 bonus token
            # always commits, so tokens/step/slot = accepted_per_step + 1
            "accepted_per_step": (self._accepted_drafts / self._spec_slot_steps
                                  if self._spec_slot_steps else 0.0),
            "steps": int(st.size),
            "decode_tokens": self._decode_tokens,
            "tokens_per_s": (self._decode_tokens / total) if total > 0 else 0.0,
            "admission_wait_s_mean": (float(np.mean(self._admission_waits))
                                      if self._admission_waits else 0.0),
            "step_time_p50_s": float(np.percentile(st, 50)) if st.size else 0.0,
            "step_time_p99_s": float(np.percentile(st, 99)) if st.size else 0.0,
            "requests_finished": len(self._retired) - self._rejected,
            "requests_rejected": self._rejected,
        }

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        n0 = len(self._retired)
        for _ in range(max_steps):
            self.step()
            if not self.busy():
                break
        return self._retired[n0:]

    # ----------------------------------------------------- straggler guard
    def straggler_p99(self) -> float:
        if not self.step_times:
            return 0.0
        return float(np.percentile(np.asarray(self.step_times), 99))

"""Serving engine: continuous batching over a fixed slot grid, FP4 weights,
prefill/decode split, CREST runtime fault detection, straggler mitigation.

The ZettaLith analogy (paper Sections 14, 19-20): a rack serves one model
from resident (HBM) FP4 weights; batch size is chosen to balance HBM weight
streaming against compute (Table 9/10); CREST continuously shadow-tests
columns; failed components are mapped out without draining traffic.

Software mapping: ``ServeEngine`` owns a slot grid of ``max_batch`` decode
streams. Each step: (1) admit queued requests into free slots via prefill,
(2) decode one token for every active slot, (3) optionally run a CREST probe
on the lm_head matmul, (4) retire finished streams. ``elastic.py`` handles
replica failure by re-queueing in-flight requests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crest
from repro.core.cascade import CascadeConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    created_at: float = 0.0
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    eos_id: int = -1              # -1: only stop at max_new_tokens
    crest_enabled: bool = False
    crest_every: int = 4          # run a BIST probe wave every N engine steps
    crest_cfg: crest.CrestConfig = dataclasses.field(default_factory=crest.CrestConfig)
    greedy: bool = True


class ServeEngine:
    def __init__(self, model, params, ccfg: CascadeConfig, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.ccfg = ccfg
        self.scfg = scfg
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * scfg.max_batch
        self.caches: List[Any] = [None] * scfg.max_batch
        self.crest_state = None
        self.fault_mask = None          # set by tests/demos to inject faults
        self._probe_w = None
        self._steps = 0
        if scfg.crest_enabled:
            self._probe_w = self._dense_head_weight()
            self.crest_state = crest.crest_init(self._probe_w.shape[1], scfg.crest_cfg)
        self._decode_fn = jax.jit(
            lambda p, t, c: model.decode_step(p, {"tokens": t}, c, ccfg))
        self.step_times: list = []

    def _dense_head_weight(self):
        """Dense view of the lm_head weight used for CREST BIST probes
        (paper Section 20.6: CREST doubles as POST/BIST with test vectors)."""
        from repro.core import cascade as C
        head = self.params.get("lm_head")
        if head is None:
            return None
        return C.linear_weight(head, self.ccfg)

    # ------------------------------------------------------------ admission
    def submit(self, req: Request):
        req.created_at = time.monotonic()
        self.queue.append(req)

    def _admit(self):
        for i in range(self.scfg.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, cache = self.model.prefill(
                    self.params, {"tokens": toks}, self.ccfg, max_len=self.scfg.max_len)
                nxt = int(jnp.argmax(logits[0, -1]))
                req.tokens_out.append(nxt)
                self.slots[i] = req
                self.caches[i] = cache

    # --------------------------------------------------------------- decode
    def _active(self):
        return [i for i, r in enumerate(self.slots) if r is not None]

    def step(self) -> int:
        """One engine step; returns number of tokens produced."""
        self._admit()
        active = self._active()
        if not active:
            return 0
        t0 = time.monotonic()
        produced = 0
        self._steps += 1
        if self.scfg.crest_enabled and self._steps % self.scfg.crest_every == 0:
            self._crest_probe()
        for i in active:  # slot-wise decode (per-slot caches keep failover simple)
            req = self.slots[i]
            tok = jnp.asarray([[req.tokens_out[-1]]], jnp.int32)
            logits, self.caches[i] = self._decode_fn(self.params, tok, self.caches[i])
            nxt = int(jnp.argmax(logits[0, -1] if logits.ndim == 3 else logits[0, -1, 0]))
            req.tokens_out.append(nxt)
            produced += 1
            if len(req.tokens_out) >= req.max_new_tokens or nxt == self.scfg.eos_id:
                req.done = True
                self.slots[i] = None
                self.caches[i] = None
        self.step_times.append(time.monotonic() - t0)
        return produced

    def _crest_probe(self):
        """BIST probe wave (paper Section 20.6): run the CREST-protected
        matmul on the lm_head weight with pseudo-random test activations;
        detected faults accumulate in ``crest_state`` and are repaired via
        spare recomputation. ``fault_mask`` lets demos inject defects."""
        if self._probe_w is None:
            return
        key = jax.random.PRNGKey(self._steps)
        x = jax.random.normal(key, (4, self._probe_w.shape[0]), jnp.float32)
        _, self.crest_state = crest.crest_matmul(
            x, self._probe_w.astype(jnp.float32), self.crest_state,
            self.scfg.crest_cfg, self.fault_mask)

    def crest_report(self) -> dict:
        if self.crest_state is None:
            return {}
        return {"confirmed_faults": int(self.crest_state.confirmed_faults.sum()),
                "repaired": int(self.crest_state.n_repaired)}

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen = set()
        for _ in range(max_steps):
            active_before = [r for r in self.slots if r is not None]
            self.step()
            for r in active_before:
                if r.done and id(r) not in seen:
                    seen.add(id(r))
                    finished.append(r)
            if len(self.queue) == 0 and not self._active():
                break
        return finished

    # ----------------------------------------------------- straggler guard
    def straggler_p99(self) -> float:
        if not self.step_times:
            return 0.0
        return float(np.percentile(np.asarray(self.step_times), 99))

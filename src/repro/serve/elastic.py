"""Elastic serving: replica failure, fail-in-place, straggler mitigation.

ZettaLith's system-level fault story (paper Section 20): defective TRIMERA
stacks are mapped out at boot or at runtime ("fail-in-place"), the rack
keeps serving with 0.64% capacity loss per stack. At multi-pod TPU scale the
analogous events are chip/host failures and stragglers. This module provides
the replica-set controller used by the serving example:

* N replicas (each a ServeEngine over its own mesh slice / process),
* health scoring from per-step latency EWMAs,
* **fail-in-place**: a replica marked dead stops receiving new admissions;
  its in-flight requests are re-queued to survivors (idempotent regenerate —
  decode state is reconstructible from the prompt + emitted tokens). This
  holds for EVERY cache family, not just full-attention KV: ring-buffer KV,
  RG-LRU/conv state and SSD state are all deterministic functions of the
  token prefix, so the survivor's (chunked) re-prefill rebuilds them
  exactly — there is nothing replica-local to checkpoint. It also holds
  mid-SPECULATION — greedy AND sampled: ``tokens_out`` only ever contains
  tokens the verify/acceptance pass committed (accepted drafts + the
  bonus/resampled token — rejected drafts are rolled back before the
  engine step returns), so the rebuilt prompt carries exactly the
  client-visible stream and never an unverified draft.

  **RNG-counter caveat (sampled serving):** the fold_in draw counter is
  engine-local state and is NOT carried by failover — the survivor
  continues the stream with its own seed + counter, so the continuation's
  draws DIFFER from the ones the dead replica would have made. That is by
  design: already-emitted tokens are baked into the rebuilt prompt (never
  re-drawn — the client's history is immutable), and every future token is
  drawn from the same conditional distribution either way, so the
  survivor's continuation is differently-realized but
  distribution-identical. Only greedy streams are token-exact across a
  failover,
* **straggler mitigation**: a replica whose per-step EWMA exceeds
  ``straggler_factor`` x the median of the OTHER healthy replicas is
  **demoted** — its queue is re-dispatched to faster replicas and
  least-loaded ``submit`` skips it — until its EWMA recovers below the
  factor (it keeps stepping its resident work the whole time, so nothing
  is lost). The comparison is deliberately median-of-OTHERS: with two
  replicas the fleet-median (midpoint) form can never satisfy
  ``ewma > factor * median`` for factor >= 1, so the original fleet-median
  check silently never fired on the smallest real deployment.

**Timing** is read from an injected :class:`repro.serve.traffic.Clock`
(defaulting to the first engine's clock): under ``MonotonicClock`` the
EWMAs measure wall time; under :class:`~repro.serve.traffic.VirtualClock`
a ``step_cost(i) -> seconds`` hook supplies each replica's virtual step
cost, advanced BEFORE the engine steps so committed tokens carry
end-of-step timestamps and the EWMA equals the configured cost exactly —
deterministic straggler/latency simulation for tests.

**Shard-awareness**: replicas may run on their own device meshes — a
``ServeEngine(..., mesh=...)`` next to unsharded engines, or engines on
different mesh shapes / TP policies. The failover carry is pure host-side
token state (``prompt + tokens_out``), never device state, so a rebuilt
request admits into ANY survivor: its chunked re-prefill reconstructs the
cache under the survivor's own ``NamedSharding`` placement (the sharded
slot grid of the dead replica simply becomes garbage, exactly like the
single-device case). Killing a sharded replica onto an unsharded survivor
— and the reverse — is token-exact (``tests/test_sharding.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.serve.engine import Request, ServeEngine


def rebuild_request(req: Request) -> Request:
    """Failover clone of an in-flight request (the decode-state rebuild).

    The clone's prompt is the current prompt + EVERY token emitted so far:
    the survivor's admission prefill rebuilds the full cache state — KV,
    ring-buffer or recurrent — and then generates the stream's next token,
    so already-emitted history is never recomputed (which also makes
    failover safe under temperature sampling, where a re-draw could rewrite
    a token the client has already seen). Under speculative decode the
    carry is automatically accepted-tokens-only: the engine appends to
    ``tokens_out`` strictly after verification, so a replica dying between
    a verify pass and its rewind can never leak rejected drafts into the
    rebuilt prompt (greedy or sampled acceptance alike — under sampled
    speculation the survivor's fresh RNG counter makes the continuation
    differently-realized but distribution-identical, see the module
    docstring). Retirement still fires at the
    ORIGINAL max_new_tokens since ``tokens_out`` carries over;
    ``prompt_carried`` records how many ``tokens_out`` entries the prompt
    now contains, so repeated failures never double-bake tokens.
    Mid-prefill requests (no new tokens yet) are returned unchanged.
    """
    new = req.tokens_out[req.prompt_carried:]   # emitted since last rebuild
    if not new:
        return req
    clone = Request(uid=req.uid,
                    prompt=np.concatenate([req.prompt, np.asarray(new, np.int32)]),
                    max_new_tokens=req.max_new_tokens,
                    slo_ttft_s=req.slo_ttft_s,
                    deadline_s=req.deadline_s)
    clone.tokens_out = list(req.tokens_out)
    clone.prompt_carried = len(clone.tokens_out)
    # latency telemetry spans replicas: the re-routed stream keeps its
    # original arrival and already-committed token timestamps, so its
    # TTFT/inter-token record describes what the CLIENT saw, not what the
    # survivor did (engine.submit only stamps created_at when it is 0.0)
    clone.created_at = req.created_at
    clone.first_token_at = req.first_token_at
    clone.token_times = list(req.token_times)
    return clone


@dataclasses.dataclass
class ReplicaHealth:
    alive: bool = True
    demoted: bool = False        # straggling: keeps stepping, no new work
    ewma_ms: float = 0.0
    steps: int = 0


class ReplicaSet:
    def __init__(self, engines: List[ServeEngine], straggler_factor: float = 3.0,
                 clock=None,
                 step_cost: Optional[Callable[[int], float]] = None):
        self.engines = engines
        self.health = [ReplicaHealth() for _ in engines]
        self.straggler_factor = straggler_factor
        #: all replica timing reads this clock (default: the engines' own)
        self.clock = clock if clock is not None else engines[0].clock
        #: virtual-time hook: seconds one step of replica i costs. When set,
        #: the clock is advanced by that cost BEFORE ``eng.step()`` so the
        #: tokens committed inside the step are stamped with the step's END
        #: time, and the EWMA equals the configured cost exactly.
        self.step_cost = step_cost
        self.requeued: list = []   # clones created by failover (for tracking)
        self._rr = 0

    # ------------------------------------------------------------ dispatch
    def submit(self, req: Request) -> int:
        alive = [i for i, h in enumerate(self.health) if h.alive]
        assert alive, "no healthy replicas"
        # least-loaded among healthy non-stragglers (queued + resident +
        # mid-prefill); if EVERY survivor is demoted, fall back to all alive
        # rather than dropping the request on the floor
        pool = [i for i in alive if not self.health[i].demoted] or alive
        i = min(pool, key=lambda j: self.engines[j].load())
        self.engines[i].submit(req)
        return i

    def step(self) -> int:
        produced = 0
        for i, (eng, h) in enumerate(zip(self.engines, self.health)):
            if not h.alive:
                continue
            if self.step_cost is not None:
                # virtual-time path: an idle replica takes no step and pays
                # no cost (its EWMA freezes; a demoted straggler recovers by
                # stepping its RESIDENT work at the improved cost)
                if not eng.busy():
                    continue
                dt_ms = float(self.step_cost(i)) * 1e3
                advance = getattr(self.clock, "advance", None)
                if advance is not None:
                    advance(dt_ms * 1e-3)    # pay BEFORE stepping: commits
                produced += eng.step()       # carry end-of-step timestamps
            else:
                t0 = self.clock.now()
                produced += eng.step()
                dt_ms = (self.clock.now() - t0) * 1e3
            h.ewma_ms = dt_ms if h.steps == 0 else 0.9 * h.ewma_ms + 0.1 * dt_ms
            h.steps += 1
        self._mitigate_stragglers()
        return produced

    # ------------------------------------------------------------- failure
    def kill_replica(self, i: int):
        """Simulate a hard replica loss; re-queue its in-flight work.

        Works for both engine modes, every cache family, and any mesh
        placement: ``abort_in_flight`` frees the slot grid (batched mode:
        the stacked-cache slots — sharded or not — simply become garbage)
        and ``rebuild_request`` reconstructs decode state — full-attention
        KV, ring-buffer KV or recurrent {conv, h}/{conv, ssd} — from the
        prompt + emitted tokens on a survivor, under the survivor's own
        sharding."""
        self.health[i].alive = False
        eng = self.engines[i]
        for req in eng.abort_in_flight():
            clone = rebuild_request(req)
            if clone is not req:
                self.requeued.append(clone)
            self.submit(clone)
        # not-yet-admitted requests move to survivors unchanged
        for req in list(eng.queue):
            self.submit(req)
        eng.queue.clear()

    def _mitigate_stragglers(self):
        """Demote stragglers / recover demoted replicas.

        Each candidate's EWMA is compared against the median of the OTHER
        alive, non-demoted, warmed-up replicas (NOT the fleet median: with
        2 replicas the fleet median is the midpoint, so
        ``ewma > factor * median`` reduces to ``e > factor*(1+e)/2`` —
        unsatisfiable for factor >= 1 — and demotion would never fire on
        the smallest real deployment). Demotion moves the straggler's
        queued-but-unadmitted work to faster replicas and flips
        ``demoted`` so ``submit`` skips it; resident work keeps stepping.
        Recovery flips it back once the EWMA is at or below the factor."""
        for i, h in enumerate(self.health):
            if not (h.alive and h.steps > 4):
                continue
            others = [o.ewma_ms for j, o in enumerate(self.health)
                      if j != i and o.alive and not o.demoted and o.steps > 4]
            if not others:
                # nothing to compare against — and never demote the only
                # dispatch target
                h.demoted = False
                continue
            bar = self.straggler_factor * max(float(np.median(others)), 1e-6)
            if not h.demoted and h.ewma_ms > bar:
                h.demoted = True
                # stop admitting; queued work re-routes to faster replicas,
                # resident work finishes in place
                for req in list(self.engines[i].queue):
                    self.submit(req)
                self.engines[i].queue.clear()
            elif h.demoted and h.ewma_ms <= bar:
                h.demoted = False

    def drain(self, max_steps: int = 100_000):
        for _ in range(max_steps):
            if all((not h.alive) or not e.busy()
                   for e, h in zip(self.engines, self.health)):
                break
            self.step()

"""Elastic serving: replica failure, fail-in-place, straggler mitigation.

ZettaLith's system-level fault story (paper Section 20): defective TRIMERA
stacks are mapped out at boot or at runtime ("fail-in-place"), the rack
keeps serving with 0.64% capacity loss per stack. At multi-pod TPU scale the
analogous events are chip/host failures and stragglers. This module provides
the replica-set controller used by the serving example:

* N replicas (each a ServeEngine over its own mesh slice / process),
* health scoring from per-step latency EWMAs,
* **fail-in-place**: a replica marked dead stops receiving new admissions;
  its in-flight requests are re-queued to survivors (idempotent regenerate —
  decode state is reconstructible from the prompt + emitted tokens),
* **straggler mitigation**: requests on a replica whose p99 step latency
  exceeds ``straggler_factor`` x the fleet median are eligible for
  speculative re-dispatch to the fastest healthy replica.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serve.engine import Request, ServeEngine


@dataclasses.dataclass
class ReplicaHealth:
    alive: bool = True
    ewma_ms: float = 0.0
    steps: int = 0


class ReplicaSet:
    def __init__(self, engines: List[ServeEngine], straggler_factor: float = 3.0):
        self.engines = engines
        self.health = [ReplicaHealth() for _ in engines]
        self.straggler_factor = straggler_factor
        self.requeued: list = []   # clones created by failover (for tracking)
        self._rr = 0

    # ------------------------------------------------------------ dispatch
    def submit(self, req: Request):
        alive = [i for i, h in enumerate(self.health) if h.alive]
        assert alive, "no healthy replicas"
        # least-loaded among healthy (queued + resident + mid-prefill)
        i = min(alive, key=lambda j: self.engines[j].load())
        self.engines[i].submit(req)

    def step(self) -> int:
        produced = 0
        for i, (eng, h) in enumerate(zip(self.engines, self.health)):
            if not h.alive:
                continue
            import time
            t0 = time.monotonic()
            produced += eng.step()
            dt = (time.monotonic() - t0) * 1e3
            h.ewma_ms = dt if h.steps == 0 else 0.9 * h.ewma_ms + 0.1 * dt
            h.steps += 1
        self._mitigate_stragglers()
        return produced

    # ------------------------------------------------------------- failure
    def kill_replica(self, i: int):
        """Simulate a hard replica loss; re-queue its in-flight work.

        Works for both engine modes: ``abort_in_flight`` frees the slot grid
        (batched mode: the stacked-cache slots simply become garbage — decode
        state is reconstructible from the prompt + emitted tokens)."""
        self.health[i].alive = False
        eng = self.engines[i]
        for req in eng.abort_in_flight():
            new = req.tokens_out[req.prompt_carried:]   # emitted since last rebuild
            if not new:                 # mid-prefill: nothing new to bake in
                self.submit(req)
                continue
            # decode state is reconstructible: the clone's prompt is the
            # current prompt + all-but-the-last NEW token; admission prefill
            # regenerates that last token (greedy decode is deterministic),
            # and retirement still fires at the ORIGINAL max_new_tokens
            # since tokens_out carries over. ``prompt_carried`` records how
            # many tokens_out entries the prompt now contains, so repeated
            # failures never double-bake tokens.
            re = Request(uid=req.uid,
                         prompt=np.concatenate([req.prompt, np.asarray(new[:-1], np.int32)])
                         if len(new) > 1 else req.prompt,
                         max_new_tokens=req.max_new_tokens)
            re.tokens_out = list(req.tokens_out[:-1])
            re.prompt_carried = len(re.tokens_out)
            self.requeued.append(re)
            self.submit(re)
        # not-yet-admitted requests move to survivors unchanged
        for req in list(eng.queue):
            self.submit(req)
        eng.queue.clear()

    def _mitigate_stragglers(self):
        alive = [h for h in self.health if h.alive and h.steps > 4]
        if len(alive) < 2:
            return
        med = np.median([h.ewma_ms for h in alive])
        for i, h in enumerate(self.health):
            if h.alive and h.steps > 4 and h.ewma_ms > self.straggler_factor * max(med, 1e-6):
                # demote: stop admitting; current work finishes, queue drains
                for req in list(self.engines[i].queue):
                    self.submit(req)
                self.engines[i].queue.clear()

    def drain(self, max_steps: int = 100_000):
        for _ in range(max_steps):
            if all((not h.alive) or not e.busy()
                   for e, h in zip(self.engines, self.health)):
                break
            self.step()

"""SLO-aware routing of open-loop traffic over a ``ReplicaSet``.

The missing layer between ``traffic.poisson_trace`` (arrivals the system
does not control) and ``elastic.ReplicaSet`` (N engines with health
state): a router that owns the admission queue and makes the three
decisions a rack-scale front-end makes per request (paper Sections 2, 19;
the serving-scaling survey in PAPERS.md frames tail-latency-under-load as
the rack-scale metric):

* **Dispatch** — least-loaded among alive, non-demoted replicas, and only
  when the target has *headroom* (``engine.load() < max_batch``). The
  headroom gate is what makes shedding possible at all: work the fleet
  cannot start yet stays in the ROUTER's queue where the deadline check
  can still reach it, instead of being buried in an engine queue that
  admits strictly FIFO.
* **Shedding** — a request whose admission deadline (``deadline_s`` after
  arrival) passes before dispatch is dropped and counted in
  ``requests_shed``; serving it would burn fleet capacity on a response
  the client has already abandoned. Shed requests count as SLO misses —
  honest accounting, no survivorship bias.
* **Failover** — ``kill_replica`` mid-trace re-routes in-flight work via
  ``elastic.rebuild_request`` with zero lost tokens; the rebuilt stream
  keeps its original ``created_at`` and committed ``token_times``, so its
  latency record describes what the client saw across both replicas.

``run_trace`` is clock-dual: under a ``VirtualClock`` it fast-forwards
idle gaps (``advance_to`` the next arrival) and replica step costs come
from ``ReplicaSet.step_cost`` — two runs of the same seeded trace produce
IDENTICAL per-request TTFT/inter-token records; under the wall
``MonotonicClock`` it sleeps until the next arrival and latency is real.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.elastic import ReplicaSet
from repro.serve.engine import Request, ServeEngine
from repro.serve.traffic import Arrival


class SLORouter:
    def __init__(self, replicas: ReplicaSet):
        self.replicas = replicas
        self.clock = replicas.clock
        self.pending: deque = deque()       # arrived, not yet dispatched
        self.shed: List[Request] = []       # deadline-expired, never served
        self._offered = 0

    # ------------------------------------------------------------ admission
    def offer(self, req: Request):
        """One arrival. ``created_at`` must already be stamped (open-loop:
        the arrival instant, not the dispatch instant)."""
        assert req.created_at > 0.0, "open-loop arrivals are pre-stamped"
        self._offered += 1
        self.pending.append(req)

    def _dispatch(self) -> int:
        """Shed the overdue, forward the rest while replicas have headroom."""
        sent = 0
        while self.pending:
            now = self.clock.now()
            req = self.pending[0]
            if req.deadline_s > 0.0 and now - req.created_at > req.deadline_s:
                self.pending.popleft()
                req.done = True
                req.finished_at = now
                self.shed.append(req)
                continue
            i = self._target()
            if i is None:                    # no headroom anywhere: requests
                break                        # wait HERE, still sheddable
            self.pending.popleft()
            self.replicas.engines[i].submit(req)
            sent += 1
        return sent

    def _target(self) -> Optional[int]:
        """Least-loaded alive non-demoted replica with admission headroom
        (falls back to demoted-but-alive if every survivor is demoted)."""
        hs = self.replicas.health
        alive = [i for i, h in enumerate(hs) if h.alive]
        assert alive, "no healthy replicas"
        pool = [i for i in alive if not hs[i].demoted] or alive
        pool = [i for i in pool
                if self.replicas.engines[i].load()
                < self.replicas.engines[i].scfg.max_batch]
        if not pool:
            return None
        return min(pool, key=lambda j: self.replicas.engines[j].load())

    # ------------------------------------------------------------- the loop
    def run_trace(self, trace: Sequence[Arrival],
                  kills: Sequence[Tuple[float, int]] = (),
                  max_steps: int = 100_000) -> List[Request]:
        """Drive a full open-loop trace to completion; returns the final
        per-request records (one per uid — see ``results``).

        ``trace`` arrival times are trace-relative; they are re-based onto
        this clock's epoch and each request's ``created_at`` is stamped
        with its re-based ARRIVAL time, so queueing delay (router + engine)
        is charged to TTFT. ``kills`` is a list of ``(at_s, replica)``
        fail-in-place events, also trace-relative; killed replicas' work
        re-routes to survivors token-exactly."""
        t0 = self.clock.now()
        arrivals = deque(sorted(trace, key=lambda a: a.at_s))
        for a in arrivals:
            assert a.request.created_at == 0.0, "trace already run"
            a.request.created_at = t0 + a.at_s
        kill_q = deque(sorted((t0 + t, i) for t, i in kills))
        for _ in range(max_steps):
            now = self.clock.now()
            while kill_q and kill_q[0][0] <= now:
                self.replicas.kill_replica(kill_q.popleft()[1])
            while arrivals and t0 + arrivals[0].at_s <= now:
                self.offer(arrivals.popleft().request)
            self._dispatch()
            busy = any(h.alive and e.busy() for e, h in
                       zip(self.replicas.engines, self.replicas.health))
            if busy or self.pending:
                self.replicas.step()
            elif arrivals or kill_q:
                # fleet idle: jump/sleep to the next scheduled event
                nxt = min(([t0 + arrivals[0].at_s] if arrivals else [])
                          + ([kill_q[0][0]] if kill_q else []))
                # clock-dual by protocol: the virtual clock jumps, the
                # wall clock really sleeps (Clock.sleep_until — serve/
                # never reads time.* directly)
                self.clock.sleep_until(nxt)
            else:
                return self.results()
        raise RuntimeError(f"trace did not drain in {max_steps} steps")

    # -------------------------------------------------------------- results
    def results(self) -> List[Request]:
        """Final record per uid, shed requests included.

        A failover leaves TWO objects per re-routed stream (the aborted
        original and the survivor's rebuilt clone, which carries the full
        telemetry); the clone retires with more committed tokens, so
        keeping the record with the longest ``tokens_out`` (ties: latest
        ``finished_at``) yields exactly what the client observed."""
        best: Dict[int, Request] = {}
        everything = [r for e in self.replicas.engines for r in e._retired]
        everything += self.shed
        for r in everything:
            cur = best.get(r.uid)
            if (cur is None
                    or (len(r.tokens_out), r.finished_at)
                    > (len(cur.tokens_out), cur.finished_at)):
                best[r.uid] = r
        return sorted(best.values(), key=lambda r: r.uid)

    def metrics(self) -> dict:
        """Fleet-level per-request telemetry: latency percentiles over the
        final records plus honest three-way accounting — ``finished``
        (served to completion), ``shed`` (router deadline), ``rejected``
        (engine admission: un-servable prompt). SLO attainment counts a
        request as attained only if it produced a first token within its
        ``slo_ttft_s``; shed and rejected SLO-stamped requests are MISSES,
        not exclusions."""
        recs = self.results()
        shed_uids = {r.uid for r in self.shed}
        em = [e.metrics() for e in self.replicas.engines]
        rejected = sum(m["requests_rejected"] for m in em)
        slo = [r for r in recs if r.slo_ttft_s > 0.0]
        attained = [r for r in slo if r.first_token_at > 0.0
                    and r.first_token_at - r.created_at <= r.slo_ttft_s]
        # prefix-cache counters aggregate across replicas (each replica owns
        # its own page pool and radix tree — hits are per-replica locality)
        phits = sum(m["prefix_hits"] for m in em)
        plook = sum(m["prefix_lookups"] for m in em)
        return {
            "prefix_hits": phits,
            "prefix_lookups": plook,
            "prefix_hit_rate": (phits / plook) if plook else 0.0,
            "pages_in_use": sum(m["pages_in_use"] for m in em),
            "evictions": sum(m["evictions"] for m in em),
            **ServeEngine.latency_percentiles(recs),
            "requests_offered": self._offered,
            "requests_finished": sum(1 for r in recs
                                     if r.uid not in shed_uids
                                     and r.first_token_at > 0.0),
            "requests_shed": len(self.shed),
            "requests_rejected": rejected,
            "slo_attainment": (len(attained) / len(slo)) if slo else 1.0,
            "replicas_alive": sum(h.alive for h in self.replicas.health),
            "replicas_demoted": sum(h.demoted for h in self.replicas.health
                                    if h.alive),
        }

"""Model-free draft proposals for speculative decode (prompt lookup).

The ZettaLith economics (paper Table 9/10): a decode step streams the full
weight set from HBM whether it scores 1 token or K+1, so any token the
verify pass accepts beyond the first is nearly free. The cheapest drafter
that exploits this is **prompt lookup / n-gram** (no second model, no extra
weights to stream): repeated spans — code, templated text, self-repetition
in long generations — are predicted by finding the current suffix n-gram
earlier in the stream and proposing whatever followed it.

Correctness never depends on draft quality. Under greedy decode the verify
pass only commits draft tokens that match the model's own argmax; under
sampled decode (``temperature > 0``) the engine runs **speculative
sampling** against this drafter's distribution. A prompt-lookup proposal is
deterministic given the context, so its per-position draft distribution q
is a **point mass** (a delta) at the proposed token — the accept/resample
rule in ``serve/engine.spec_sample_accept`` is specialized to exactly that
q. Either way a bad draft costs nothing (the step still commits one token,
exactly like plain decode) and a good draft commits up to K+1.

Because q must be a distribution over REAL proposals, the drafter reports
``k_eff`` — how many of the k returned tokens were actually proposed.
Zero-padding alone cannot carry that information: token id 0 is a
legitimate vocab token, and a padded 0 scored as a real proposal would be
accepted with probability p(0) under sampling (and could spuriously match
argmax 0 under greedy) even though it was never drawn from q.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def ngram_propose(context: np.ndarray, k: int, ngram_max: int) -> Tuple[np.ndarray, int]:
    """Propose up to ``k`` draft tokens by prompt lookup over ``context``.

    Finds the longest suffix n-gram (n = ngram_max .. 1) of ``context`` that
    also occurs earlier, and returns ``(draft, k_eff)``: the tokens that
    followed an earlier occurrence, zero-padded at the tail, plus the number
    ``k_eff`` of REAL proposals among them (padding must never be scored as
    a proposal — see module docstring). A miss returns ``(zeros, 0)``.

    Among the earlier occurrences, the most recent one with a FULL k-token
    continuation wins; if none has k tokens available before the context
    end, the most recent occurrence wins with a short (``k_eff < k``)
    continuation. Self-repetitive tails make the most recent match sit
    flush against the context end, where only 1 continuation token exists —
    preferring a full continuation keeps the proposal length (and thus the
    speculative ceiling) at k instead of collapsing to 1.
    """
    ctx = np.asarray(context, np.int32).ravel()
    out = np.zeros(k, np.int32)
    n_ctx = len(ctx)
    if n_ctx < 2 or k <= 0:
        return out, 0
    for n in range(min(ngram_max, n_ctx - 1), 0, -1):
        suffix = ctx[n_ctx - n:]
        # windows of length n starting at 0 .. n_ctx-n-1 (exclude the suffix
        # occurrence itself)
        wins = np.lib.stride_tricks.sliding_window_view(ctx, n)[:-1]
        hits = np.nonzero((wins == suffix).all(axis=1))[0]
        if hits.size:
            full = hits[hits + n + k <= n_ctx]
            start = int(full[-1] if full.size else hits[-1]) + n
            cont = ctx[start:start + k]
            out[:len(cont)] = cont
            return out, len(cont)
    return out, 0

"""Model-free draft proposals for speculative decode (prompt lookup).

The ZettaLith economics (paper Table 9/10): a decode step streams the full
weight set from HBM whether it scores 1 token or K+1, so any token the
verify pass accepts beyond the first is nearly free. The cheapest drafter
that exploits this is **prompt lookup / n-gram** (no second model, no extra
weights to stream): repeated spans — code, templated text, self-repetition
in long generations — are predicted by finding the current suffix n-gram
earlier in the stream and proposing whatever followed it.

Correctness never depends on draft quality: the engine's verify pass only
commits draft tokens that match the model's own greedy argmax, so a bad
draft costs nothing (the step still commits one token, exactly like plain
decode) and a good draft commits up to K+1.
"""
from __future__ import annotations

import numpy as np


def ngram_propose(context: np.ndarray, k: int, ngram_max: int) -> np.ndarray:
    """Propose ``k`` draft tokens by prompt lookup over ``context``.

    Finds the longest suffix n-gram (n = ngram_max .. 1) of ``context`` that
    also occurs earlier, and returns the ``k`` tokens that followed its most
    recent earlier occurrence, zero-padded at the tail. A miss returns
    zeros — a guaranteed-rejected (but free) guess.
    """
    ctx = np.asarray(context, np.int32).ravel()
    out = np.zeros(k, np.int32)
    n_ctx = len(ctx)
    if n_ctx < 2 or k <= 0:
        return out
    for n in range(min(ngram_max, n_ctx - 1), 0, -1):
        suffix = ctx[n_ctx - n:]
        # windows of length n starting at 0 .. n_ctx-n-1 (exclude the suffix
        # occurrence itself)
        wins = np.lib.stride_tricks.sliding_window_view(ctx, n)[:-1]
        hits = np.nonzero((wins == suffix).all(axis=1))[0]
        if hits.size:
            start = int(hits[-1]) + n          # most recent continuation
            cont = ctx[start:start + k]
            out[:len(cont)] = cont
            return out
    return out

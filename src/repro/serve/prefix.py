"""Host-side page allocator + radix-tree prefix cache for paged serving.

The device keeps one fixed-shape page pool per cache leaf (see
``models/cache_utils.py``); everything that DECIDES which page holds what
lives here, in plain Python, where it is cheap and unit-testable:

* :class:`PagePool` — refcounted physical pages. Page 0 is the reserved
  TRASH page (never allocated): freed slots zero their block-table rows so
  stale device writes/reads land there harmlessly.
* :class:`RadixPrefixCache` — a radix tree over token-id prefixes at page
  granularity. A node's path from the root spells out a prompt prefix in
  whole pages; the node holds the ONE physical page id whose K/V encodes
  that page's tokens *given that prefix* (a page id is valid across every
  layer's pool — all layers allocate in lockstep). Admission walks the
  tree, bumps refcounts on matched pages, and the engine starts the
  suffix prefill at the matched length instead of position 0.

Ownership contract: a page's refcount = (#slots whose block table maps it)
+ (1 if a tree node holds it). Shared pages are provably never written —
slot writes happen at rows >= pos >= matched length, and a partially
matched page is copy-on-write cloned (``copy_page`` callback, device copy)
before the divergent stream touches it. Eviction removes least-recently
used refcount-1 leaves (tree-only pages) once the pool crosses the
pressure watermark, iteratively exposing parents.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class PagePool:
    """Refcounted allocator over ``num_pages`` physical pages.

    Page 0 is the trash page: pinned at construction, never handed out.
    ``alloc`` returns a page with refcount 1; ``ref``/``release`` adjust
    ownership; a page returns to the free list when its count hits 0.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self.refcount = np.zeros(num_pages, np.int64)
        self.refcount[0] = 1                       # trash page, pinned forever
        self._free: List[int] = list(range(num_pages - 1, 0, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                "page pool exhausted — raise ServeConfig.num_pages or lower "
                "the eviction watermark")
        pg = self._free.pop()
        assert self.refcount[pg] == 0
        self.refcount[pg] = 1
        return pg

    def ref(self, pg: int) -> None:
        assert 0 < pg < self.num_pages and self.refcount[pg] > 0
        self.refcount[pg] += 1

    def release(self, pg: int) -> None:
        assert 0 < pg < self.num_pages and self.refcount[pg] > 0
        self.refcount[pg] -= 1
        if self.refcount[pg] == 0:
            self._free.append(pg)


@dataclasses.dataclass
class _Node:
    key: Tuple[int, ...]                 # this node's page_size token ids
    page: int                            # physical page holding their K/V
    parent: Optional["_Node"]
    children: Dict[Tuple[int, ...], "_Node"] = dataclasses.field(default_factory=dict)
    last_use: int = 0


@dataclasses.dataclass
class PrefixMatch:
    pages: List[int]        # physical pages backing the matched prefix
    matched: int            # tokens resolved (n_full_pages*ps [+ COW tail])
    hit_full: int           # tokens served straight from tree pages
    cow: bool               # last page is a fresh private copy


class RadixPrefixCache:
    """Page-granular radix tree over token-id prefixes.

    ``copy_page(src) -> Optional[int]`` is the engine-supplied COW hook: it
    allocates a fresh page (evicting under pressure if it must), device-
    copies ``src`` into it, and returns the new id — or ``None`` when the
    pool genuinely cannot produce a page, in which case the partial-page
    match is simply skipped (correct, just colder).
    """

    def __init__(self, pool: PagePool, page_size: int,
                 copy_page: Optional[Callable[[int], Optional[int]]] = None):
        self.pool = pool
        self.page_size = page_size
        self.copy_page = copy_page
        self.root = _Node(key=(), page=-1, parent=None)
        self._tick = 0
        self.evictions = 0

    # ------------------------------------------------------------------ walk
    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.last_use = self._tick

    def match(self, prompt: Sequence[int]) -> PrefixMatch:
        """Resolve the longest cached prefix of ``prompt``.

        Matched pages get a refcount bump (the caller now owns them via its
        block table). The match is capped at ``len(prompt) - 1`` so at
        least one prompt token is always computed — the logits that seed
        generation must come from a real forward pass.
        """
        ps = self.page_size
        toks = [int(t) for t in prompt]
        limit = len(toks) - 1
        pages: List[int] = []
        node = self.root
        i = 0
        while (i + 1) * ps <= limit:
            key = tuple(toks[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                break
            self.pool.ref(child.page)
            pages.append(child.page)
            self._touch(child)
            node = child
            i += 1
        matched = i * ps
        hit_full = matched
        cow = False
        # partial-page tail: if some child shares a strict prefix of the
        # next page chunk, clone it (COW) and resume mid-page.
        if self.copy_page is not None:
            tail = toks[i * ps:limit]
            best, best_r = None, 0
            for key, child in node.children.items():
                r = 0
                for a, b in zip(key, tail):
                    if a != b:
                        break
                    r += 1
                if r > best_r:
                    best, best_r = child, r
            if best is not None and best_r > 0:
                dst = self.copy_page(best.page)
                if dst is not None:
                    self._touch(best)
                    pages.append(dst)
                    matched += best_r
                    hit_full += best_r
                    cow = True
        return PrefixMatch(pages=pages, matched=matched, hit_full=hit_full,
                           cow=cow)

    def insert(self, prompt: Sequence[int], slot_pages: Sequence[int]) -> int:
        """Publish a freshly prefilled prompt's full pages into the tree.

        ``slot_pages`` is the slot's block list; page ``i`` holds tokens
        ``[i*ps, (i+1)*ps)``. Already-published pages are just touched; new
        nodes take a tree ref on the slot's page (which the slot keeps
        using — shared from this moment on, and past its write frontier so
        never written again). Returns the number of nodes added.
        """
        ps = self.page_size
        toks = [int(t) for t in prompt]
        n_full = min(len(toks) // ps, len(slot_pages))
        node = self.root
        added = 0
        for i in range(n_full):
            key = tuple(toks[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                pg = int(slot_pages[i])
                if pg <= 0:                      # unbacked block: stop here
                    break
                self.pool.ref(pg)
                child = _Node(key=key, page=pg, parent=node)
                node.children[key] = child
                added += 1
            self._touch(child)
            node = child
        return added

    # -------------------------------------------------------------- eviction
    def _evictable_leaves(self) -> List[_Node]:
        out: List[_Node] = []

        def walk(n: _Node):
            for c in n.children.values():
                walk(c)
            if n is not self.root and not n.children \
                    and self.pool.refcount[n.page] == 1:
                out.append(n)

        walk(self.root)
        return out

    def evict(self, n_pages: int) -> int:
        """Drop up to ``n_pages`` least-recently-used tree-only pages.

        Only refcount-1 leaves are candidates (pages a slot still maps are
        pinned; interior nodes become leaves as their children go). Returns
        the number of pages actually freed.
        """
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_use)
            self.pool.release(victim.page)
            del victim.parent.children[victim.key]
            self.evictions += 1
            freed += 1
        return freed

    def maybe_evict(self, watermark_pages: int) -> int:
        """Enforce the pool-pressure watermark: evict LRU tree pages until
        ``pages_in_use <= watermark_pages`` (or nothing is evictable)."""
        over = self.pool.pages_in_use - watermark_pages
        return self.evict(over) if over > 0 else 0

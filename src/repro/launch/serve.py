"""Serving launcher: FP4 weights, continuous batching, optional CREST,
mesh-native sharded decode.

CPU smoke:  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b \
                --smoke --requests 16 --prompt-len 12 --max-new 8

Host-mesh demo (8 virtual CPU devices, CASCADE column-parallel params +
slot-sharded caches; token-exact with the single-device run):

    PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b \
        --smoke --requests 8 --max-batch 4 --host-devices 8 --mesh 4x2

``--tp-policy megatron`` serves the row+column baseline (its decode step
carries the partial-sum all-reduces CASCADE abolishes — compare with
``--verify-hlo``, which prints the partial-sum all-reduce count of the
compiled decode step and fails if a cascade-policy step has any).

``--audit`` runs the full serving-contract auditor (repro.analysis) over
every step closure of the engine as configured — the launcher-local slice
of what ``python -m benchmarks.audit`` checks matrix-wide in CI.
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import cascade
from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.engine import Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--no-fp4", action="store_true", help="serve bf16 baseline")
    ap.add_argument("--fused", action="store_true",
                    help="route decode/extend/verify through the Pallas "
                         "kernels (packed-FP4 matmul + decode attention); "
                         "needs FP4 params — incompatible with --no-fp4 and "
                         "--mesh (downgrades with a warning)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="serve from the paged KV pool with the radix-tree "
                         "prefix cache: shared prompt prefixes admit "
                         "through already-resident pages instead of "
                         "re-prefilling (token-exact with the dense "
                         "engine; ring/recurrent archs downgrade with a "
                         "warning)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page under --prefix-cache")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="> 0 enables seeded sampling (default: greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k best logits (0 = all)")
    ap.add_argument("--draft-len", type=int, default=0,
                    help="speculative decode: K drafted tokens per slot per "
                         "step (0 = off). Composes with --temperature: "
                         "sampled serving runs speculative sampling "
                         "(rejection resampling, distribution-exact with "
                         "plain sampled decode)")
    ap.add_argument("--ngram-max", type=int, default=3,
                    help="longest suffix n-gram the prompt-lookup drafter matches")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N virtual CPU devices (must be set before "
                         "first jax use; the CI/laptop stand-in for a mesh)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve on a (data, model) mesh, e.g. 4x2 ('auto' "
                         "splits the available devices)")
    ap.add_argument("--tp-policy", default="cascade",
                    choices=["cascade", "megatron"],
                    help="param placement when --mesh is set")
    ap.add_argument("--verify-hlo", action="store_true",
                    help="print the decode step's partial-sum all-reduce "
                         "count; exit 1 if a cascade-policy step has any")
    ap.add_argument("--audit", action="store_true",
                    help="audit EVERY jitted step closure of the "
                         "constructed engine against the serving contract "
                         "(donation, host transfers, dtypes, collective "
                         "budget — repro.analysis.contract) and exit 1 on "
                         "any error finding; composes with --mesh/--fused/"
                         "--prefix-cache to audit exactly what would serve")
    ap.add_argument("--traffic", action="store_true",
                    help="live-traffic demo: route a seeded open-loop "
                         "Poisson trace (--rate, --requests arrivals) over "
                         "--replicas engine replicas via the SLO-aware "
                         "router, and print per-request TTFT/inter-token "
                         "percentiles + SLO attainment")
    ap.add_argument("--replicas", type=int, default=2,
                    help="engine replicas under --traffic")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (requests/s) under --traffic")
    ap.add_argument("--slo-ttft", type=float, default=0.5,
                    help="per-request TTFT SLO seconds under --traffic "
                         "(0 = none)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="admission deadline seconds under --traffic "
                         "(0 = never shed)")
    ap.add_argument("--kill-at", type=float, default=None, metavar="T",
                    help="with --traffic: fail replica 0 in place T seconds "
                         "into the trace (its streams re-route token-exact)")
    args = ap.parse_args()

    from repro.launch import mesh as meshlib
    if args.host_devices:
        meshlib.force_host_device_count(args.host_devices)

    import jax
    mesh = meshlib.make_serving_mesh(args.mesh) if args.mesh else None
    cfg, model = registry.load(args.arch, smoke=args.smoke)
    compute = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16
    train_ccfg = CascadeConfig(mode="train", compute_dtype=compute)
    params = model.init_params(jax.random.PRNGKey(0), train_ccfg)
    if args.no_fp4:
        ccfg = train_ccfg
    else:
        ccfg = CascadeConfig(mode="serve_fp4", compute_dtype=compute)
        params = cascade.tree_to_serve_fp4(params, ccfg)

    scfg = ServeConfig(max_batch=args.max_batch,
                       max_len=args.prompt_len + args.max_new + 1,
                       temperature=args.temperature, top_k=args.top_k,
                       draft_len=args.draft_len, ngram_max=args.ngram_max,
                       tp_policy=args.tp_policy, fused=args.fused,
                       prefix_cache=args.prefix_cache,
                       page_size=args.page_size)

    if args.traffic:
        if mesh is not None or args.verify_hlo:
            print("--traffic is a replica-fleet demo; run it without "
                  "--mesh/--verify-hlo")
            raise SystemExit(2)
        from repro.serve.elastic import ReplicaSet
        from repro.serve.router import SLORouter
        from repro.serve.traffic import TrafficConfig, poisson_trace
        engines = [ServeEngine(model, params, ccfg, scfg)
                   for _ in range(args.replicas)]
        rs = ReplicaSet(engines)
        router = SLORouter(rs)
        trace = poisson_trace(TrafficConfig(
            rate_rps=args.rate, n_requests=args.requests,
            prompt_lens=((max(1, args.prompt_len // 2), args.prompt_len),),
            output_lens=((max(1, args.max_new // 2), args.max_new),),
            vocab=cfg.vocab, slo_ttft_s=args.slo_ttft,
            deadline_s=args.deadline))
        kills = [(args.kill_at, 0)] if args.kill_at is not None else []
        t0 = time.time()
        recs = router.run_trace(trace, kills=kills)
        dt = time.time() - t0
        m = router.metrics()
        print(f"traffic: {m['requests_offered']} arrivals at "
              f"{args.rate:g} req/s over {args.replicas} replicas "
              f"({m['replicas_alive']} alive after "
              f"{len(kills)} kill(s)) in {dt:.2f}s")
        print(f"  ttft p50/p99 {m['ttft_p50_s']*1e3:.1f}/"
              f"{m['ttft_p99_s']*1e3:.1f} ms, inter-token p50/p99 "
              f"{m['inter_token_p50_s']*1e3:.1f}/"
              f"{m['inter_token_p99_s']*1e3:.1f} ms")
        print(f"  SLO attainment {m['slo_attainment']:.3f} "
              f"(finished {m['requests_finished']}, shed "
              f"{m['requests_shed']}, rejected {m['requests_rejected']})")
        for r in recs[:3]:
            print(f"  req {r.uid}: {r.tokens_out}")
        return

    eng = ServeEngine(model, params, ccfg, scfg, mesh=mesh)

    # never let "nothing was checked" look like "the invariant holds"
    if args.verify_hlo and mesh is None:
        print("--verify-hlo requires --mesh: a single-device decode step "
              "has no collectives, so its zero verifies nothing")
        raise SystemExit(2)
    if args.verify_hlo and not eng.batched:
        print("--verify-hlo requires the batched engine; this model fell "
              "back to the slot-wise path, nothing was verified")
        raise SystemExit(2)
    if args.audit:
        from repro.analysis import contract, format_findings, gating
        res = contract.audit_engine(eng)
        for name, st in res["closures"].items():
            print(f"audit {name}: aliases={st['donation_aliases']} "
                  f"host_xfer={st['host_transfers']} "
                  f"psum_ar={st['partial_sum_allreduces']} "
                  f"packed_params={st['packed_params']}")
        bad = gating(res["findings"])
        if res["findings"]:
            print(format_findings(res["findings"]))
        print(f"audit: {len(res['closures'])} closure(s), "
              f"{len(res['findings'])} finding(s), {len(bad)} gating")
        if bad:
            print("SERVING CONTRACT VIOLATED", flush=True)
            raise SystemExit(1)

    if args.verify_hlo:
        try:
            from benchmarks import hlo_analysis
        except ImportError:
            print("--verify-hlo needs benchmarks/ on the path (run from the "
                  "repo root)")
            raise SystemExit(2)
        ar = hlo_analysis.partial_sum_allreduces(eng.decode_step_hlo())
        print(f"decode-step partial-sum all-reduces: {ar['count']} "
              f"({ar['bytes']} B) under tp_policy={args.tp_policy}")
        if args.tp_policy == "cascade" and ar["count"]:
            print("CASCADE invariant VIOLATED", flush=True)
            raise SystemExit(1)
        if eng.spec:
            # with --temperature > 0 this lowers the FUSED sampled
            # verify+accept/resample step the engine actually dispatches
            arv = hlo_analysis.partial_sum_allreduces(
                eng.decode_step_hlo("verify"))
            print(f"spec-verify partial-sum all-reduces: {arv['count']} "
                  f"({arv['bytes']} B) under tp_policy={args.tp_policy}")
            if args.tp_policy == "cascade" and arv["count"]:
                print("CASCADE invariant VIOLATED (spec verify)", flush=True)
                raise SystemExit(1)

    rng = np.random.default_rng(0)
    # under --prefix-cache the demo requests share their first half (the
    # "same system prompt, different user turn" shape the radix cache
    # serves): every admission after the first re-pins the resident pages
    shared = (rng.integers(0, cfg.vocab,
                           size=args.prompt_len // 2).astype(np.int32)
              if args.prefix_cache and args.prompt_len >= 2 else None)
    tail = args.prompt_len - (len(shared) if shared is not None else 0)

    def _prompt():
        p = rng.integers(0, cfg.vocab, size=tail).astype(np.int32)
        return p if shared is None else np.concatenate([shared, p])

    reqs = [Request(uid=i, prompt=_prompt(), max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    total = 0
    while eng.busy():
        total += eng.step()
    dt = time.time() - t0
    m = eng.metrics()
    spec = (f", spec draft_len={m['draft_len']} "
            f"accepted/step={m['accepted_per_step']:.2f}" if m["spec"] else "")
    mstr = (f", mesh={m['mesh']} tp={m['tp_policy']}" if m["mesh"] else "")
    print(f"mode={m['effective_mode']}"
          + (f" (downgraded: {'; '.join(m['downgrades'])})"
             if m["downgrades"] else ""))
    print(f"served {args.requests} requests, {total} tokens in {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s), p99 step {eng.straggler_p99()*1e3:.1f} ms, "
          f"batched={m['batched']}{spec}{mstr}, admission wait {m['admission_wait_s_mean']*1e3:.1f} ms")
    if m["paged"]:
        print(f"  prefix cache: hit rate {m['prefix_hit_rate']:.2f} "
              f"({m['prefix_hits']}/{m['prefix_lookups']} tokens), "
              f"pages {m['pages_in_use']}/{m['pages_total']} "
              f"(page_size={m['page_size']}), evictions {m['evictions']}")
    for r in reqs[:3]:
        print(f"  req {r.uid}: {r.tokens_out}")


if __name__ == "__main__":
    main()

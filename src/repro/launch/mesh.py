"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The single-pod mesh is 16x16 = 256 chips
(data, model); the multi-pod mesh is 2x16x16 = 512 chips (pod, data, model),
where the ``pod`` axis composes with ``data`` for batch sharding — the
paper's optional multi-rack 800 GbE expansion (Section 17.1) maps to the
pod axis' DCN-class links.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the runtime has
    them (jax >= 0.5 exposes jax.sharding.AxisType; older releases only
    build Auto meshes, so the kwarg is simply dropped)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = ({"axis_types": (axis_type.Auto,) * len(axes)}
              if axis_type is not None else {})
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over however many devices exist (CPU tests)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))

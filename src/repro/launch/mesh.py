"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The single-pod mesh is 16x16 = 256 chips
(data, model); the multi-pod mesh is 2x16x16 = 512 chips (pod, data, model),
where the ``pod`` axis composes with ``data`` for batch sharding — the
paper's optional multi-rack 800 GbE expansion (Section 17.1) maps to the
pod axis' DCN-class links.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the runtime has
    them (jax >= 0.5 exposes jax.sharding.AxisType; older releases only
    build Auto meshes, so the kwarg is simply dropped)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = ({"axis_types": (axis_type.Auto,) * len(axes)}
              if axis_type is not None else {})
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over however many devices exist (CPU tests)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def force_host_device_count(n: int):
    """Ask XLA for ``n`` host (CPU) devices, the CI/laptop stand-in for a
    real accelerator mesh.

    Sets (or raises) ``--xla_force_host_platform_device_count`` in
    ``XLA_FLAGS``; jax reads the flag at backend init, so this must run
    before the first device query. The function SELF-VERIFIES by counting
    devices afterwards (initializing the backend, which the caller's mesh
    construction was about to do anyway): if the count still falls short —
    the backend was already up when we were called — it raises the
    actionable set-it-before-starting error instead of letting mesh
    construction fail with an opaque shape mismatch.
    """
    import os
    import re
    if n <= 1:
        return
    prev = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", prev)
    if m is None:
        os.environ["XLA_FLAGS"] = (
            prev + f" --xla_force_host_platform_device_count={n}").strip()
    elif int(m.group(1)) < n:
        # a smaller inherited count would make the requested mesh unbuildable
        os.environ["XLA_FLAGS"] = prev.replace(
            m.group(0), f"--xla_force_host_platform_device_count={n}")
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"jax already initialized with {len(jax.devices())} device(s); "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} in "
            "the environment before starting the process")


def parse_mesh_shape(spec: str) -> tuple[int, int]:
    """'4x2' -> (data=4, model=2); 'auto' splits the available devices into
    (n // m, m) with the model axis as wide a power of two as divides n
    (capped at 8 — serving TP beyond 8-way wants a real topology choice)."""
    if spec == "auto":
        n = len(jax.devices())
        m = 1
        while m < 8 and n % (m * 2) == 0:
            m *= 2
        return n // m, m
    d, _, m = spec.partition("x")
    return int(d), int(m)


def make_serving_mesh(spec: str = "auto"):
    """(data, model) host mesh for ``ServeEngine(..., mesh=...)``."""
    data, model = parse_mesh_shape(spec)
    return make_mesh((data, model), ("data", "model"))

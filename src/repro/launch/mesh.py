"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The single-pod mesh is 16x16 = 256 chips
(data, model); the multi-pod mesh is 2x16x16 = 512 chips (pod, data, model),
where the ``pod`` axis composes with ``data`` for batch sharding — the
paper's optional multi-rack 800 GbE expansion (Section 17.1) maps to the
pod axis' DCN-class links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over however many devices exist (CPU tests)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

"""Training launcher.

CPU smoke:      PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b \
                    --smoke --steps 20 --batch 8 --seq 64
Production:     same CLI on a TPU pod slice; --mesh production selects the
                16x16 mesh from launch/mesh.py and shards via
                distributed/sharding.py (CASCADE policy by default).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cascade import CascadeConfig
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import registry
from repro.optim.adamw import AdamW
from repro.train import checkpoint as ckpt
from repro.train import loop as train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--qat", action="store_true", help="FP4 quantization-aware training")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="host", choices=["host", "production"])
    ap.add_argument("--tp-policy", default="cascade", choices=["cascade", "megatron"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg, model = registry.load(args.arch, smoke=args.smoke)
    compute = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16
    ccfg = CascadeConfig(mode="train", qat=args.qat, compute_dtype=compute)
    opt = AdamW(lr=args.lr, warmup_steps=max(2, args.steps // 10), decay_steps=args.steps)

    mesh = (make_production_mesh() if args.mesh == "production" else make_host_mesh())
    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))

    state = train_loop.init_state(model, ccfg, opt)
    start_step = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        pspecs = shd.param_specs(state.params, args.tp_policy,
                                 tied_embed=cfg.tie_embeddings)
        shardings = train_loop.TrainState(
            params=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                is_leaf=lambda x: isinstance(x, P)),
            opt=None, step=None)
        state, extra = ckpt.restore(state, args.ckpt_dir)
        start_step = int(extra.get("data_step", 0))
        print(f"resumed at step {start_step}")

    step_fn = jax.jit(train_loop.make_train_step(
        model, ccfg, opt, microbatches=args.microbatches,
        remat=jax.default_backend() != "cpu"))

    losses = []
    t0 = time.time()
    with mesh:
        for i in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, data.batch_at(i))
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                print(f"step {i:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
            if args.ckpt_every and args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                ckpt.save(state, args.ckpt_dir, i + 1,
                          extra={"data_step": i + 1}, async_=True)

    print(f"final loss {np.mean(losses[-5:]):.4f} "
          f"(first {np.mean(losses[:5]):.4f}) in {time.time() - t0:.1f}s")
    return losses


if __name__ == "__main__":
    main()

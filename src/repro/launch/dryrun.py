import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production meshes, with no real hardware.

For each cell this proves:
  * the sharding config is coherent (GSPMD partitions the step without
    falling back to replication errors or unsupported collectives),
  * per-device memory fits (``compiled.memory_analysis()``),
  * and it extracts the roofline inputs (``compiled.cost_analysis()`` +
    collective bytes parsed from the compiled HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun
"""

import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.core.cascade import CascadeConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.optim.adamw import AdamW, AdamWState
from repro.train import loop as train_loop

# ---------------------------------------------------------------------------
# HLO collective-bytes parser (§Roofline: collective term)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "f8e4m3fn": 1,
    "f8e5m2": 1, "f4e2m1fn": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in (per-device) HLO text.

    Counts each op's output shape once (operand size ~= output size for
    gather/permute; for all-reduce output == operand). Ops inside while
    bodies appear once — the caller scales loop-resident ops by trip count
    (see benchmarks/roofline.py).
    """
    stats = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", ls)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-start") or opname == c + "-done":
                base = c
                break
        if base is None:
            continue
        if opname.endswith("-done"):
            continue  # counted at -start
        b = _tensor_bytes(shape_str)
        stats[base]["count"] += 1
        stats[base]["bytes"] += b
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

def build_step(arch_id: str, shape_name: str, ccfg: CascadeConfig,
               tp_policy: str = "cascade", dp_shard: str = "none",
               full_dp: bool = False, remat_policy: str = "dots",
               microbatches: int = 1):
    """full_dp: batch sharded over ALL mesh axes (pure data parallelism);
    combined with dp_shard='fsdp' this is FSDP/ZeRO-3 — weights stay sharded
    and GSPMD all-gathers them per layer inside the scan."""
    """Returns (fn, abstract_args, in_specs_builder) for the cell."""
    cfg, model = registry.load(arch_id)
    shape = cfgbase.SHAPES[shape_name]
    specs = cfgbase.input_specs(cfg, shape)
    batch_axes = ("pod", "data", "model") if full_dp else ("pod", "data")
    # tied-embedding archs keep a replicated table under cascade: a
    # d-sharded table would make the tied head contract over a sharded dim
    # (a partial-sum all-reduce the policy forbids)
    tied = cfg.tie_embeddings

    params_shape = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), ccfg))

    if shape.kind == "train":
        opt = AdamW()
        state_shape = jax.eval_shape(
            lambda: train_loop.TrainState(
                params=model.init_params(jax.random.PRNGKey(0), ccfg),
                opt=opt.init(model.init_params(jax.random.PRNGKey(0), ccfg)),
                step=jnp.int32(0)))
        step_fn = train_loop.make_train_step(model, ccfg, opt, remat=True,
                                             remat_policy=remat_policy,
                                             microbatches=microbatches)
        abstract = (state_shape, specs)

        def in_specs(mesh):
            pspecs = shd.param_specs(params_shape, tp_policy, tied_embed=tied)
            mspecs = pspecs
            if dp_shard in ("zero1", "fsdp"):
                mspecs = shd.add_data_dim(pspecs, params_shape, mesh)
            if dp_shard == "fsdp":
                pspecs = mspecs
            state_specs = train_loop.TrainState(
                params=pspecs,
                opt=AdamWState(step=P(), mu=mspecs, nu=mspecs),
                step=P())
            return (state_specs, shd.batch_specs(specs, batch_axes=batch_axes, mesh=mesh))

        return step_fn, abstract, in_specs

    if shape.kind == "prefill":
        def step_fn(params, batch):
            return model.prefill(params, batch, ccfg, max_len=shape.seq_len)

        abstract = (params_shape, specs)

        def in_specs(mesh):
            return (shd.param_specs(params_shape, tp_policy, tied_embed=tied),
                    shd.batch_specs(specs, mesh=mesh))

        return step_fn, abstract, in_specs

    # decode: one new token against a cache of seq_len
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 dtype=ccfg.resolved_kv_dtype))

    def step_fn(params, batch, cache):
        return model.decode_step(params, batch, cache, ccfg)

    abstract = (params_shape, specs, cache_shape)

    def in_specs(mesh):
        return (shd.param_specs(params_shape, tp_policy, tied_embed=tied),
                shd.batch_specs(specs, mesh=mesh),
                shd.cache_specs(cache_shape, mesh))

    return step_fn, abstract, in_specs


def lower_cell(arch_id: str, shape_name: str, mesh, ccfg=None,
               tp_policy: str = "cascade", verbose: bool = True,
               return_compiled: bool = False, act_policy: str = "cascade",
               dp_shard: str = "none", full_dp: bool = False,
               remat_policy: str = "dots", microbatches: int = 1,
               moe_ep: bool = False) -> dict:
    """Lower + compile one cell; return the §Dry-run/§Roofline record."""
    ccfg = ccfg or CascadeConfig(mode="serve_fp4" if "train" not in shape_name
                                 else "train", qat=False)
    cfg = registry.get_config(arch_id)
    shape = cfgbase.SHAPES[shape_name]
    if not cfgbase.shape_applicable(cfg, shape):
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch: long_500k requires sub-quadratic attention"}

    t0 = time.time()
    step_fn, abstract, in_specs_fn = build_step(arch_id, shape_name, ccfg, tp_policy,
                                                 dp_shard, full_dp, remat_policy,
                                                 microbatches)
    in_specs = in_specs_fn(mesh)
    in_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        in_specs, is_leaf=lambda x: isinstance(x, P))

    shd.set_activation_policy(mesh, act_policy, moe_ep=moe_ep)
    try:
        with mesh:
            lowered = jax.jit(step_fn, in_shardings=in_shardings).lower(*abstract)
            compiled = lowered.compile()
    finally:
        shd.clear_activation_policy()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per device
        cost = cost[0] if cost else {}
    coll = collective_stats(compiled.as_text())
    record = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "tp_policy": tp_policy,
        "act_policy": act_policy,
        "dp_shard": dp_shard,
        "full_dp": full_dp,
        "moe_ep": moe_ep,
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "flops_per_device": cost.get("flops", -1.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", -1.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "collectives": coll,
    }
    if verbose:
        print(json.dumps(record, indent=None, default=str))
    if return_compiled:
        record["_compiled"] = compiled
    return record


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(cfgbase.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--tp-policy", default="cascade", choices=["cascade", "megatron"])
    ap.add_argument("--act-policy", default="cascade",
                    choices=["none", "cascade", "seqpar", "fulldp"])
    ap.add_argument("--dp-shard", default="none", choices=["none", "zero1", "fsdp"])
    ap.add_argument("--full-dp", action="store_true")
    ap.add_argument("--remat-policy", default="dots", choices=["dots", "none", "save_all"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = list(registry.ALIASES.keys()) if (args.all or args.arch is None) else [args.arch]
    shapes = list(cfgbase.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    records = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for arch in archs:
            for shape in shapes:
                try:
                    rec = lower_cell(arch, shape, mesh, tp_policy=args.tp_policy,
                                     act_policy=args.act_policy, dp_shard=args.dp_shard,
                                     full_dp=args.full_dp, remat_policy=args.remat_policy)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {"arch": arch, "shape": shape, "mesh": dict(mesh.shape),
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    print(json.dumps(rec, default=str))
                records.append(rec)

    if args.out:
        import os as _os
        _os.makedirs(_os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1, default=str)
    n_fail = sum(r["status"] == "FAILED" for r in records)
    print(f"\n{len(records)} cells: {len(records) - n_fail} ok/skipped, {n_fail} FAILED",
          file=sys.stderr)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

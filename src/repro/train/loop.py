"""Training loop: jit'd QAT train step, microbatch grad accumulation, remat,
fault-tolerant checkpointing hooks.

The step is a pure function (state, batch) -> (state, metrics) so it lowers
identically for the CPU smoke tests, the single-pod dry-run and the
multi-pod mesh — only the in/out shardings differ (see launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.cascade import CascadeConfig
from repro.optim.adamw import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE. Handles the musicgen (B,S,C,V) codebook layout too."""
    vocab = logits.shape[-1]
    lg = logits.reshape(-1, vocab)
    lab = labels.reshape(-1)
    ll = jnp.take_along_axis(jax.nn.log_softmax(lg.astype(jnp.float32), -1),
                             lab[:, None], axis=1)
    return -jnp.mean(ll)


def make_train_step(model, ccfg: CascadeConfig, optimizer: AdamW,
                    microbatches: int = 1, remat: bool = True,
                    remat_policy: str = "dots"):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatches`` > 1 splits the batch and accumulates gradients with a
    lax.scan — the standard memory/throughput knob at large global batch.
    ``remat_policy``: dots (save matmul outputs) | none (full recompute,
    minimum memory — the right setting for FSDP where re-gathering weights
    in backward is cheaper than storing per-layer activations) | save_all.
    """

    def loss_fn(params, batch):
        logits = model.forward(params, batch, ccfg, remat=remat,
                               remat_policy=remat_policy)
        return cross_entropy(logits, batch["labels"])

    def train_step(state: TrainState, batch: dict):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, b):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, b)
                return carry, (loss, grads)

            _, (losses, grads_all) = jax.lax.scan(acc, (), mb)
            loss = jnp.mean(losses)
            grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_all)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        new_params, new_opt, om = optimizer.update(grads, state.opt, state.params)
        metrics = {"loss": loss, **om}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def init_state(model, ccfg: CascadeConfig, optimizer: AdamW, seed: int = 0) -> TrainState:
    params = model.init_params(jax.random.PRNGKey(seed), ccfg)
    return TrainState(params=params, opt=optimizer.init(params), step=jnp.int32(0))

"""Checkpoint/restart for fault tolerance at cluster scale.

Numpy-file based (no external deps): each pytree leaf is one ``.npy`` under
``step_N/``, with a JSON manifest of flattened key-paths, shapes, dtypes and
the data-pipeline cursor. Properties needed at 1000+ nodes:

* **async save** — a snapshot is taken on host (device_get) and written by a
  background thread; training continues immediately.
* **atomic publish** — writes go to ``step_N.tmp/`` and are renamed only
  after fsync, so a node failure mid-save never corrupts the latest
  checkpoint; restore picks the newest complete step.
* **elastic restore** — leaves are loaded host-side and ``device_put`` with
  whatever sharding the *new* mesh prescribes, so a job can restart on a
  different pod count (the paper's fail-in-place at rack scale = drop a pod,
  re-mesh, continue).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        names.append("/".join(parts))
        leaves.append(leaf)
    return names, leaves, jax.tree_util.tree_structure(tree)


def save(tree: Any, directory: str, step: int, extra: Optional[dict] = None,
         async_: bool = False) -> Optional[threading.Thread]:
    """Snapshot ``tree`` and write it to ``directory/step_{step}``."""
    names, leaves, _ = _flatten_with_names(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    def _write():
        tmp = os.path.join(directory, f"step_{step}.tmp")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (name, arr) in enumerate(zip(names, host_leaves)):
            fn = f"leaf_{i}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"name": name, "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(tree_like: Any, directory: str, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``. ``shardings``: optional
    matching tree of jax.sharding.Sharding for elastic re-mesh placement."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _flatten_with_names(tree_like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree_util.tree_leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for name, like, shd in zip(names, leaves, shard_leaves):
        e = by_name[name]
        arr = np.load(os.path.join(d, e["file"]))
        assert tuple(arr.shape) == tuple(like.shape), f"{name}: {arr.shape} vs {like.shape}"
        arr = arr.astype(like.dtype) if hasattr(like, "dtype") else arr
        out.append(jax.device_put(arr, shd) if shd is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

"""The one finding type every analysis half emits.

A finding is a violated invariant with enough context to act on: which
named check fired (``check``), where (``closure name`` for the HLO auditor,
``file:line`` for the lint), and what was measured. ``level`` separates
gating errors from informational records ("check skipped on this backend"
must be VISIBLE, never silent — a skipped check that looks like a pass is
the failure mode this subsystem exists to kill). ``allowlisted`` findings
stay in the report but do not gate.
"""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass
class Finding:
    check: str              # named rule/checker, e.g. "donation", "time-read"
    where: str              # closure name or file:line
    detail: str             # what was measured vs what the contract says
    level: str = "error"    # "error" gates; "info" records a skipped check
    allowlisted: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(check=str(d.get("check", "?")),
                   where=str(d.get("where", "?")),
                   detail=str(d.get("detail", "")),
                   level=str(d.get("level", "error")),
                   allowlisted=bool(d.get("allowlisted", False)))


def gating(findings: List[Finding]) -> List[Finding]:
    """The findings that should fail a gate: errors not allowlisted."""
    return [f for f in findings if f.level == "error" and not f.allowlisted]


def format_findings(findings: List[Finding]) -> str:
    if not findings:
        return "no findings"
    lines = []
    for f in findings:
        tag = ("allow" if f.allowlisted else f.level)
        lines.append(f"[{tag}] {f.check}: {f.where} — {f.detail}")
    return "\n".join(lines)

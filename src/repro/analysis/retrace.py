"""Compile-count guard: each step closure compiles exactly once per trace.

The whole point of fixed-shape serving (stacked cache, chunked prefill,
padded token grids — PR 1) is ONE compile per closure for any workload; a
retrace mid-trace is a silent multi-second stall that per-request latency
percentiles smear into mush. This guard reads each jitted closure's
dispatch-cache size after a full serving trace:

* ``> max_compiles``  — a retrace happened: some dispatch saw a new shape/
  dtype/sharding. Error finding naming the closure.
* ``== 0``            — the closure was never dispatched; the guard
  verified nothing for it. Info finding (honest, not silent).

``jitfn._cache_size()`` is private jax API; when absent the guard reports
an info finding per closure instead of pretending to pass. AOT lowering
(``engine.lower_step`` / ``contract.audit_engine``) does NOT populate the
dispatch cache, so run the trace first, snapshot, then audit — order does
not actually matter, but the trace must precede THIS check.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

from repro.analysis.findings import Finding


def compile_counts(engine) -> Dict[str, int]:
    """Dispatch-cache sizes per step closure; -1 when the private
    ``_cache_size`` API is unavailable on this jax version."""
    out: Dict[str, int] = {}
    for name, entry in engine.step_closures().items():
        sz = getattr(entry["fn"], "_cache_size", None)
        try:
            out[name] = int(sz()) if callable(sz) else -1
        except Exception:
            out[name] = -1
    return out


def retrace_findings(engine, max_compiles: int = 1,
                     require_dispatched: Iterable[str] = ()) -> List[Finding]:
    """Findings over a traced engine's compile counts.

    ``require_dispatched`` names closures the caller KNOWS the trace
    exercised (e.g. ``decode``/``extend`` on any non-empty trace) — zero
    compiles there upgrades the info finding to an error, because the
    guard silently verifying nothing is itself a contract violation.
    """
    required = set(require_dispatched)
    findings: List[Finding] = []
    for name, n in compile_counts(engine).items():
        if n < 0:
            findings.append(Finding(
                "retrace", name, "jit cache size unavailable on this jax — "
                "retrace guard skipped", level="info"))
        elif n > max_compiles:
            findings.append(Finding(
                "retrace", name,
                f"compiled {n}x over the trace (expected <= {max_compiles})"
                f" — a shape/dtype/sharding leaked into dispatch; every "
                f"extra compile is a silent multi-second stall"))
        elif n == 0:
            findings.append(Finding(
                "retrace", name,
                "never dispatched over the trace — the retrace guard "
                "verified nothing for this closure",
                level="error" if name in required else "info"))
    return findings

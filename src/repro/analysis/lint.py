"""Repo lint: established serving invariants as named AST rules.

The HLO auditor (``analysis/contract.py``) checks the compiled program;
this half checks the SOURCE for contracts that never reach HLO:

* ``time-read``        — no direct ``time.*`` reads (or ``time`` imports)
                         in ``serve/`` outside ``traffic.py``. The PR-8
                         clock contract: every serving-path latency number
                         reads the injected ``Clock``, so traffic tests
                         replay deterministically under ``VirtualClock``.
* ``host-sync-in-jit`` — no ``np.*`` / ``.item()`` / ``device_get`` on
                         traced values inside a function passed to
                         ``jax.jit``: a host sync inside a step closure
                         either crashes under tracing or silently fences
                         the dispatch pipeline.
* ``jax-config-global``— no process-global ``jax.config.update`` outside
                         designated (allowlisted) sites; a stray flag flip
                         re-bases RNG streams / numerics for every other
                         engine in the process.
* ``pallas-interpret`` — every ``pl.pallas_call`` site must thread an
                         ``interpret=`` kwarg, so each kernel stays
                         reachable in interpret mode (the CPU-exact parity
                         path every kernel test relies on).

Findings carry ``file:line``. Allowlist a site by putting
``# lint: allow[rule-name] — reason`` on the flagged line or the line
directly above it; allowlisted findings stay visible in reports but do not
gate. Run as ``python -m repro.analysis.lint [root ...]`` (default:
``src/repro``); exits 1 on unallowlisted findings.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Dict, List, Optional

from repro.analysis.findings import Finding, format_findings, gating

RULES = {
    "time-read": "serve/ reads the injected Clock, never time.* directly "
                 "(traffic.py owns the one wall-clock shim)",
    "host-sync-in-jit": "no np.*/.item()/device_get on traced values "
                        "inside jit-closure bodies",
    "jax-config-global": "no process-global jax.config mutation outside "
                         "designated sites",
    "pallas-interpret": "every pl.pallas_call site threads interpret=",
}

_HOST_NP_NAMES = ("np", "numpy")


def _allowed(rule: str, lines: List[str], lineno: int) -> bool:
    """``# lint: allow[rule]`` on the flagged line or the line above."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and f"lint: allow[{rule}]" in lines[ln - 1]:
            return True
    return False


def _is_jax_jit(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "jit"
            and isinstance(node.value, ast.Name) and node.value.id == "jax")


def _jit_targets(tree: ast.AST):
    """Yield the AST nodes whose bodies run under jax.jit tracing: lambdas
    passed to ``jax.jit(...)``, local functions passed by name, and
    functions decorated with ``@jax.jit`` / ``@functools.partial(jax.jit,
    ...)``. Cross-module references cannot be resolved statically and are
    skipped."""
    local_fns: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            local_fns[node.name] = node
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func) and node.args:
            tgt = node.args[0]
            if isinstance(tgt, ast.Lambda):
                yield tgt
            elif isinstance(tgt, ast.Name) and tgt.id in local_fns:
                yield local_fns[tgt.id]
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jax_jit(dec):
                    yield node
                elif (isinstance(dec, ast.Call) and dec.args
                      and isinstance(dec.func, ast.Attribute)
                      and dec.func.attr == "partial"
                      and _is_jax_jit(dec.args[0])):
                    yield node


def _host_sync_hits(fn_node: ast.AST):
    """(lineno, what) for host-sync calls inside one jit-closure body."""
    body = fn_node.body if isinstance(fn_node.body, list) else [fn_node.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                    and f.value.id in _HOST_NP_NAMES):
                yield node.lineno, f"host numpy call {f.value.id}.{f.attr}()"
            elif isinstance(f, ast.Attribute) and f.attr == "item":
                yield node.lineno, ".item() host sync"
            elif ((isinstance(f, ast.Attribute) and f.attr == "device_get")
                  or (isinstance(f, ast.Name) and f.id == "device_get")):
                yield node.lineno, "device_get host sync"


def lint_source(src: str, rel: str) -> List[Finding]:
    """Lint one file's source. ``rel`` is the repo-relative posix path used
    both for findings and for path-scoped rules."""
    try:
        tree = ast.parse(src, filename=rel)
    except SyntaxError as e:
        return [Finding("parse", f"{rel}:{e.lineno or 0}",
                        f"unparseable: {e.msg}")]
    lines = src.splitlines()
    findings: List[Finding] = []

    def add(rule: str, lineno: int, detail: str):
        findings.append(Finding(rule, f"{rel}:{lineno}", detail,
                                allowlisted=_allowed(rule, lines, lineno)))

    in_serve = "serve/" in rel and not rel.endswith("traffic.py")
    if in_serve:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                if any(a.name == "time" for a in node.names):
                    add("time-read", node.lineno,
                        "serve/ imports time — read the injected Clock")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "time":
                    add("time-read", node.lineno,
                        "serve/ imports from time — read the injected Clock")
            elif (isinstance(node, ast.Attribute)
                  and isinstance(node.value, ast.Name)
                  and node.value.id == "time"):
                add("time-read", node.lineno,
                    f"direct time.{node.attr} read in serve/ — latency "
                    f"numbers must come from the injected Clock")

    seen = set()
    for fn in _jit_targets(tree):
        if id(fn) in seen:          # decorated AND referenced by name
            continue
        seen.add(id(fn))
        for lineno, what in _host_sync_hits(fn):
            add("host-sync-in-jit", lineno,
                f"{what} inside a jit-closure body")

    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "update"
                and isinstance(node.func.value, ast.Attribute)
                and node.func.value.attr == "config"
                and isinstance(node.func.value.value, ast.Name)
                and node.func.value.value.id == "jax"):
            add("jax-config-global", node.lineno,
                "process-global jax.config.update outside a designated "
                "(allowlisted) site")
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "pallas_call"):
            has_interpret = any(kw.arg == "interpret" or kw.arg is None
                                for kw in node.keywords)
            if not has_interpret:
                add("pallas-interpret", node.lineno,
                    "pl.pallas_call without interpret= — the kernel is "
                    "unreachable in interpret mode (CPU parity path)")
    return findings


def lint_paths(roots=("src/repro",),
               base: Optional[Path] = None) -> List[Finding]:
    """Lint every ``*.py`` under the given roots (repo-relative unless
    absolute). Findings are sorted by location."""
    base = Path(base) if base is not None else Path.cwd()
    findings: List[Finding] = []
    for root in roots:
        rootp = Path(root)
        if not rootp.is_absolute():
            rootp = base / rootp
        files = [rootp] if rootp.is_file() else sorted(rootp.rglob("*.py"))
        for f in files:
            try:
                rel = f.relative_to(base).as_posix()
            except ValueError:
                rel = f.as_posix()
            findings.extend(lint_source(f.read_text(), rel))
    findings.sort(key=lambda f: f.where)
    return findings


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    roots = args or ["src/repro"]
    findings = lint_paths(roots)
    print(format_findings(findings))
    bad = gating(findings)
    if bad:
        print(f"\n{len(bad)} unallowlisted finding(s)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

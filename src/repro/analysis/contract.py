"""The serving contract, applied to every jitted step closure.

``ServingContract`` declares the structural properties a compiled serving
step must have — the paper's architectural claims plus the invariants
PRs 1–9 accreted in prose:

* every stacked-cache donation is honored (``donation_aliases``; a dropped
  donation silently doubles KV HBM),
* no host round-trip inside a step (``host_transfers``; a step is ONE
  device dispatch),
* no forbidden dtypes, and packed (u8 codes+scales) weight params on the
  serve_fp4 path (``dtype_audit``; a densified tree is the bug FP4 serving
  exists to avoid),
* collectives within a declared budget, zero partial-sum all-reduces under
  the cascade policy (``collective_budget``; paper Sections 2.2/13.5).

``audit_engine(engine)`` AOT-lowers every closure the engine constructed
(``engine.step_closures()`` — decode/extend/write/verify/rewind/sample/
spec_sample plus the paged page ops) against the live params/cache
placement and returns structured findings plus per-closure stats. AOT
lowering never touches the jit dispatch cache, so auditing composes with
the ``analysis.retrace`` compile-count guard run on the same engine.

Backend honesty: buffer donation is probed (``donation_supported``) — the
oldest pinned jax drops CPU donations with a warning, and a check that
cannot run must surface as an INFO finding, not a silent pass. Likewise the
FP4 dot-dtype story: interpret-mode Pallas kernels legitimately dequantize
to float inside the step on CPU, so the packed-weight contract is checked
on the ENTRY signature (see ``hlo.dtype_audit``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

from repro.analysis import hlo
from repro.analysis.findings import Finding


@dataclasses.dataclass(frozen=True)
class ServingContract:
    """What a compiled serving step is allowed to look like.

    ``None`` caps mean uncapped; ``max_partial_sum_allreduces=0`` is the
    cascade-policy default (the paper's headline invariant)."""
    donated_cache: bool = True
    # leaves smaller than this are advisory for the donation check: tiny
    # position vectors may legitimately be recomputed (e.g. rewind derives
    # pos from the checkpoint) instead of aliased. Keep the floor well
    # below leaf_bytes / mesh_devices — alias sizes are per-shard.
    donation_min_bytes: int = 1024
    # closures whose cache update is in-place by construction (fixed
    # slot-grid writes), where every major leaf MUST alias. ``extend``
    # (griffin's ring normalization materializes fresh buffers) and
    # ``rewind`` (recurrent families restore wholesale from per-position
    # checkpoints — PR 3's design) donate best-effort: aliases show up in
    # stats, their absence does not gate.
    strict_donation_closures: Tuple[str, ...] = (
        "decode", "sample", "write", "verify", "spec_sample",
        "copy_page", "reset_pos")
    forbid_host_transfers: bool = True
    forbid_dtypes: Tuple[str, ...] = ("f64",)
    require_packed_weights: bool = False
    max_partial_sum_allreduces: Optional[int] = 0
    # the zero-partial-sum claim (paper 2.2/13.5) covers the decode-path
    # dispatches. Chunked prefill writes batch-1 staging state under a
    # replicated placement, which lowers masked-add all-reduces the
    # cascade activation discipline does not (yet) eliminate — measured
    # fact surfaced by this auditor, recorded in stats, tracked in
    # ROADMAP; exempt from the gate so it cannot silently regress into
    # the decode step instead.
    psum_exempt_closures: Tuple[str, ...] = ("extend",)
    max_collective_counts: Optional[Dict[str, float]] = None
    max_collective_bytes: Optional[float] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def default_contract(engine) -> ServingContract:
    """The contract the engine's own configuration promises: packed weights
    iff it serves FP4, zero partial-sum all-reduces iff the cascade policy
    placed the params (megatron is the measured baseline that HAS them)."""
    return ServingContract(
        require_packed_weights=(engine.ccfg.mode == "serve_fp4"),
        max_partial_sum_allreduces=(0 if engine.tp_policy == "cascade"
                                    else None),
    )


@functools.lru_cache(maxsize=None)
def donation_supported() -> bool:
    """Whether this backend honors buffer donation. CPU donation landed in
    newer jaxlib only; the pinned oldest CI version drops it with a warning.
    Probed once per process by compiling a trivially aliasable identity —
    when False, donation findings downgrade to info (check skipped, and the
    report says so)."""
    import warnings

    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        text = f.lower(jnp.zeros((8,), jnp.float32)).compile().as_text()
    return "input_output_alias" in text


def audit_step(name: str, text: str, contract: ServingContract, *,
               donates_cache: bool = True, takes_params: bool = True,
               cache_leaves: int = 0, cache_major_leaves: int = 0) -> Tuple[dict, list]:
    """Apply the contract to one lowered step's HLO text. Returns
    ``(stats, findings)``. Pure text-in — usable on stored HLO dumps.
    ``cache_major_leaves`` counts the donated leaves at or above the
    contract's ``donation_min_bytes`` floor (the KV planes); each must
    have an alias entry of at least that size."""
    findings = []
    al = hlo.donation_aliases(text)
    ht = hlo.host_transfers(text)
    da = hlo.dtype_audit(text, forbid=contract.forbid_dtypes)
    cb = hlo.collective_budget(
        text, max_counts=contract.max_collective_counts,
        max_bytes=contract.max_collective_bytes,
        max_partial_sum=(None if name in contract.psum_exempt_closures
                         else contract.max_partial_sum_allreduces))
    stats = {
        "donation_aliases": al["count"],
        "cache_leaves": cache_leaves if donates_cache else 0,
        "host_transfers": ht["count"],
        "packed_params": da["packed_params"],
        "float_params": da["float_params"],
        "dot_dtypes": da["dot_dtypes"],
        "partial_sum_allreduces": cb["partial_sum"]["count"],
        "collective_bytes": cb["collective_bytes"],
    }
    if (contract.donated_cache and donates_cache and cache_leaves > 0
            and name in contract.strict_donation_closures):
        major_aliases = sum(1 for a in al["aliases"]
                            if a["bytes"] >= contract.donation_min_bytes)
        if not donation_supported():
            findings.append(Finding(
                "donation", name, "buffer donation is not implemented on "
                "this backend — donation check skipped", level="info"))
        elif major_aliases < cache_major_leaves:
            findings.append(Finding(
                "donation", name,
                f"only {major_aliases}/{cache_major_leaves} donated cache "
                f"leaves >= {contract.donation_min_bytes}B were aliased to "
                f"outputs — each dropped donation keeps input AND output "
                f"cache copies live (2x KV HBM)"))
    if contract.forbid_host_transfers:
        for where, what in ht["ops"]:
            findings.append(Finding(
                "host-transfer", f"{name}:{where}",
                f"host round-trip {what!r} inside a serving step — the "
                f"step must be one pure device dispatch"))
    for where, dt in da["forbidden"]:
        findings.append(Finding(
            "dtype", f"{name}:{where}", f"forbidden dtype {dt} in a "
            f"serving step"))
    if contract.require_packed_weights and takes_params \
            and da["packed_params"] == 0:
        findings.append(Finding(
            "dtype", name,
            "no packed (u8 codes/scales) weight parameter in a serve_fp4 "
            "step — the weight tree was densified before dispatch"))
    for what, got, cap in cb["violations"]:
        findings.append(Finding(
            "collective-budget", name, f"{what}: {got:g} over budget "
            f"{cap:g}"))
    return stats, findings


def audit_engine(engine, contract: Optional[ServingContract] = None) -> dict:
    """Lower + audit every step closure of a constructed engine.

    Returns ``{"contract", "closures": {name: stats}, "findings"}`` with
    ``findings`` a list of ``Finding`` (JSON-ready via ``to_dict``). The
    caller decides gating (``findings.gating``); info-level findings record
    checks that could not run on this backend.
    """
    import jax
    contract = contract or default_contract(engine)
    if not getattr(engine, "batched", False):
        return {"contract": contract.to_dict(), "closures": {},
                "findings": [Finding(
                    "audit", "engine", "slot-wise engine has no jitted "
                    "step registry to audit", level="info")]}
    leaves = jax.tree_util.tree_leaves(engine.cache)
    n_leaves = len(leaves)
    n_major = sum(1 for l in leaves
                  if l.size * l.dtype.itemsize >= contract.donation_min_bytes)
    closures: Dict[str, dict] = {}
    out_findings = []
    for name, entry in engine.step_closures().items():
        text = engine.lower_step(name).as_text()
        stats, fs = audit_step(
            name, text, contract, donates_cache=entry["donates_cache"],
            takes_params=entry["takes_params"], cache_leaves=n_leaves,
            cache_major_leaves=n_major)
        closures[name] = stats
        out_findings.extend(fs)
    return {"contract": contract.to_dict(), "closures": closures,
            "findings": out_findings}

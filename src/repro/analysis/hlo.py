"""Structural HLO analysis: the compiled-program half of the serving
contract (moved here from ``benchmarks/hlo_analysis.py``, which remains as
an import shim).

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
empirically), which would undercount scanned-layer models by n_layers. This
module parses ``compiled.as_text()`` into a computation call graph, reads
``known_trip_count`` off every while op, and propagates multiplicities to:

* dot FLOPs (2 * prod(out_shape) * prod(contracted lhs dims)), and
* collective bytes (output tensor bytes per op, per device),

giving loop-corrected per-device totals. Convolution/elementwise FLOPs are
ignored (dots dominate every assigned arch).

On top of the parser sit the per-step invariant checkers that
``analysis/contract.py`` applies to every jitted serving closure:

* ``donation_aliases``   — which params the compiler actually aliased to
                           outputs (``input_output_alias``); a silently
                           dropped cache donation doubles KV HBM.
* ``host_transfers``     — infeed/outfeed/send/recv and host-callback
                           custom-calls; a serving step must be one pure
                           device dispatch.
* ``dtype_audit``        — per-dtype dot census, forbidden-dtype hits, and
                           the packed-vs-float ENTRY parameter split (the
                           FP4 path's weights are u8 code planes, never
                           dense floats).
* ``collective_budget``  — loop-corrected per-kind collective counts and
                           bytes against a declared budget, generalizing
                           ``partial_sum_allreduces``.

Everything here is pure text analysis — no jax import, so the checkers run
on stored HLO dumps as well as live lowerings.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f4e2m1fn": 1, "s4": 1, "u4": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# dtypes whose ENTRY parameters count as packed/code planes (the serve_fp4
# weight format stores two E2M1 nibbles per u8; scales ride as u8 E8M0)
_PACKED_DTYPES = ("u8", "s8", "u4", "s4", "f8e4m3fn", "f8e5m2", "f8e4m3",
                  "f4e2m1fn")
_FLOAT_DTYPES = ("f16", "bf16", "f32", "f64")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}]+))\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\)|[\w\[\],\{\}]+))")
# one alias entry on the HloModule header line:
#   {output_index}: (param_number, {param_index}, may-alias|must-alias)
# the kind literal disambiguates entries, so no balanced-brace scan needed
_ALIAS_RE = re.compile(
    r"\{\s*([\d,\s]*)\}:\s*\(\s*(\d+)\s*,\s*\{\s*([\d,\s]*)\}\s*,?\s*"
    r"(may-alias|must-alias)?\s*\)")
_CC_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')

# host ops by opcode; plus custom-call targets that round-trip to the host
# (python callbacks — io_callback/pure_callback/debug.callback lower to
# ``xla_python_cpu_callback`` variants — and host-memory offload moves)
_HOST_OPS = ("infeed", "outfeed", "send", "send-done", "recv", "recv-done")
_HOST_CC_MARKERS = ("callback", "host", "infeed", "outfeed")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        if dt in _DTYPE_BYTES:
            total += math.prod(dims) * _DTYPE_BYTES[dt] if dims else _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    instrs: List[Instr]


def parse_computations(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        m = _COMP_RE.match(raw)
        if m:
            is_entry, name, params_str, _ = m.groups()
            params = {}
            for pm in _PARAM_RE.finditer(params_str):
                params[pm.group(1)] = pm.group(2)
            cur = Computation(name=name, params=params, instrs=[])
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(raw)
        if im:
            cur.instrs.append(Instr(*im.groups()))
    return comps, entry


def _multiplicities(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """computation name -> times executed per program run."""
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, stack=()):
        if name not in comps or name in stack:
            return
        mult[name] += m
        for ins in comps[name].instrs:
            trip = 1.0
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.rest)
                trip = float(tm.group(1)) if tm else 1.0
            for kw in _CALL_RE.finditer(ins.rest):
                child_m = m
                if kw.group(0).startswith("body="):
                    child_m = m * trip
                elif kw.group(0).startswith("condition="):
                    child_m = m * (trip + 1)
                visit(kw.group(1), child_m, stack + (name,))

    visit(entry, 1.0)
    return dict(mult)


def analyze(text: str) -> dict:
    """Loop-corrected per-device dot FLOPs + collective bytes."""
    comps, entry = parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = _multiplicities(comps, entry)

    dot_flops = 0.0
    dot_flops_uncorrected = 0.0
    coll = {c: {"count": 0.0, "bytes": 0.0, "bytes_uncorrected": 0.0} for c in _COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        # symbol table: instruction/param name -> type string
        sym: Dict[str, str] = dict(comp.params)
        for ins in comp.instrs:
            sym[ins.name] = ins.type_str
        for ins in comp.instrs:
            if ins.op == "dot":
                out_dims = _shape_dims(ins.type_str)
                out_elems = math.prod(out_dims[0][1]) if out_dims and out_dims[0][1] else 1
                ops = _OPERANDS_RE.findall(ins.rest)
                cd = _CDIMS_RE.search(ins.rest)
                k = 1
                if ops and cd is not None and ops[0] in sym:
                    lhs_dims = _shape_dims(sym[ops[0]])
                    if lhs_dims and lhs_dims[0][1]:
                        for d in cd.group(1).split(","):
                            if d:
                                k *= lhs_dims[0][1][int(d)]
                f = 2.0 * out_elems * k
                dot_flops += m * f
                dot_flops_uncorrected += f
            else:
                base = None
                for c in _COLLECTIVES:
                    if ins.op == c or ins.op == c + "-start":
                        base = c
                        break
                if base is not None:
                    b = _type_bytes(ins.type_str)
                    coll[base]["count"] += m
                    coll[base]["bytes"] += m * b
                    coll[base]["bytes_uncorrected"] += b

    total_coll = sum(v["bytes"] for v in coll.values())
    return {
        "dot_flops": dot_flops,
        "dot_flops_uncorrected": dot_flops_uncorrected,
        "collectives": coll,
        "collective_bytes": total_coll,
    }


def partial_sum_allreduces(text: str) -> dict:
    """Count all-reduce ops whose combiner is an ADD — partial-sum traffic,
    the quantity CASCADE abolishes (paper Sections 2.2, 13.5).

    An all-reduce's reduction computation is named by ``to_apply=``; a
    combiner CONTAINING an ``add`` accumulates partial products (max/min/or
    combiners — argmax lowerings, mask folds — are not partial sums and are
    ignored). Containment rather than root-op equality matters for variadic
    all-reduces (XLA's combiner pass merges several into one op whose
    combiner ROOTs a ``tuple`` of adds), and the async ``-start`` forms of
    both all-reduce and reduce-scatter are counted — a gate must
    over-approximate, never false-negative. Returns
    ``{"count", "bytes", "ops": [(name, bytes), ...]}`` over EVERY
    computation in the module, loop bodies included — the serving assertion
    is "zero partial-sum all-reduce anywhere in the decode step", so no
    multiplicity weighting is needed.
    """
    comps, _ = parse_computations(text)
    out = {"count": 0, "bytes": 0, "ops": []}
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op not in ("all-reduce", "all-reduce-start",
                              "reduce-scatter", "reduce-scatter-start"):
                continue
            target = None
            for kw in _CALL_RE.finditer(ins.rest):
                if kw.group(0).startswith("to_apply="):
                    target = kw.group(1)
                    break
            combiner_adds = (target in comps and
                             any(i.op == "add" for i in comps[target].instrs))
            if combiner_adds:
                b = _type_bytes(ins.type_str)
                out["count"] += 1
                out["bytes"] += b
                out["ops"].append((f"{comp.name}/{ins.name}", b))
    return out


# ----------------------------------------------------------- new checkers
def donation_aliases(text: str) -> dict:
    """Parse ``input_output_alias`` off the HloModule header line.

    The compiler records every donation it HONORED as
    ``{output_index}: (param_number, {param_index}, may-alias)``; a donated
    buffer the compiler could not alias simply has no entry, so the contract
    check is "every donated cache leaf (above a size floor) has an alias
    entry" — a silently dropped donation means the step holds input AND
    output cache copies live, doubling KV HBM. Each entry carries the
    aliased ENTRY parameter's byte size (per-shard under a mesh), so the
    contract can ignore advisory-size leaves — e.g. a rewind step's tiny
    ``pos`` vector is legitimately recomputed from the checkpoint rather
    than aliased. Returns ``{"count", "bytes", "params", "aliases"}``.
    """
    head = ""
    for line in text.splitlines():
        if line.startswith("HloModule"):
            head = line
            break
    comps, entry = parse_computations(text)
    # ENTRY parameter byte sizes by position (params dict keeps order)
    param_bytes = ([_type_bytes(t) for t in comps[entry].params.values()]
                   if entry is not None else [])
    aliases = []
    if "input_output_alias" in head:
        # the alias attribute is the only place the (out, param, kind)
        # triple syntax appears, so matching entries on the whole header
        # line is safe despite the nested braces
        for m in _ALIAS_RE.finditer(head.split("input_output_alias=", 1)[1]):
            p = int(m.group(2))
            aliases.append({
                "output_index": tuple(int(x) for x in m.group(1).split(",")
                                      if x.strip()),
                "param": p,
                "param_index": tuple(int(x) for x in m.group(3).split(",")
                                     if x.strip()),
                "kind": m.group(4) or "may-alias",
                "bytes": param_bytes[p] if p < len(param_bytes) else 0,
            })
    return {
        "count": len(aliases),
        "bytes": sum(a["bytes"] for a in aliases),
        "params": sorted({a["param"] for a in aliases}),
        "aliases": aliases,
    }


def host_transfers(text: str) -> dict:
    """Host round-trips anywhere in the module: infeed/outfeed/send/recv
    opcodes plus custom-calls whose target names a python callback or host
    placement. A serving step closure must be ONE device dispatch — a host
    transfer inside it serializes every step on PCIe + the GIL. Returns
    ``{"count", "ops": [(comp/name, opcode-or-target), ...]}``.
    """
    comps, _ = parse_computations(text)
    out = {"count": 0, "ops": []}
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op in _HOST_OPS:
                out["ops"].append((f"{comp.name}/{ins.name}", ins.op))
            elif ins.op == "custom-call":
                m = _CC_TARGET_RE.search(ins.rest)
                tgt = m.group(1) if m else ""
                if any(k in tgt.lower() for k in _HOST_CC_MARKERS):
                    out["ops"].append((f"{comp.name}/{ins.name}", tgt))
    out["count"] = len(out["ops"])
    return out


def dtype_audit(text: str, forbid: Tuple[str, ...] = ("f64",)) -> dict:
    """Dtype census of a step: per-dtype dot counts, forbidden-dtype hits
    (any instruction whose output shape uses a forbidden dtype), and the
    ENTRY-parameter split into packed (u8/u4/fp8 code planes) vs dense
    float weights.

    The FP4-path contract is checked on the SIGNATURE, not the dot dtypes:
    packed serve_fp4 weights enter the step as u8 code+scale planes, while
    a silently densified tree enters as f32/bf16 — but interpret-mode
    Pallas kernels (the CPU-exact dequant paths) legitimately emit float
    dequant-dots inside the step, so "no f32 dot" would false-positive on
    every CPU run. ``contract.audit_engine`` therefore requires
    ``packed_params > 0`` on fused/FP4 steps and leaves dot dtypes as
    reported facts.
    """
    comps, entry = parse_computations(text)
    dot_dtypes: Dict[str, int] = defaultdict(int)
    forbidden: List[Tuple[str, str]] = []
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "dot":
                sd = _shape_dims(ins.type_str)
                if sd:
                    dot_dtypes[sd[0][0]] += 1
            for dt, _dims in _shape_dims(ins.type_str):
                if dt in forbid:
                    forbidden.append((f"{comp.name}/{ins.name}", dt))
    packed_params = float_params = 0
    packed_bytes = float_bytes = 0
    if entry is not None:
        for _pname, ptype in comps[entry].params.items():
            dts = {dt for dt, _ in _shape_dims(ptype)}
            b = _type_bytes(ptype)
            if dts & set(_PACKED_DTYPES):
                packed_params += 1
                packed_bytes += b
            elif dts & set(_FLOAT_DTYPES):
                float_params += 1
                float_bytes += b
    return {
        "dot_dtypes": dict(dot_dtypes),
        "forbidden": forbidden,
        "packed_params": packed_params,
        "float_params": float_params,
        "packed_param_bytes": packed_bytes,
        "float_param_bytes": float_bytes,
    }


def collective_budget(text: str, max_counts: Optional[Dict[str, float]] = None,
                      max_bytes: Optional[float] = None,
                      max_partial_sum: Optional[int] = 0) -> dict:
    """Check loop-corrected collective counts/bytes against a declared
    budget, generalizing the ``partial_sum_allreduces`` gate.

    ``max_counts`` caps the loop-corrected count per collective kind (keys
    from ``all-gather``/``all-reduce``/``reduce-scatter``/``all-to-all``/
    ``collective-permute``; missing keys are uncapped). ``max_bytes`` caps
    total loop-corrected collective bytes per step. ``max_partial_sum``
    caps add-combiner all-reduce/reduce-scatter ops (None = uncapped).
    Returns the measured facts plus ``violations`` —
    ``[(what, measured, budget), ...]``, empty when within budget.
    """
    facts = analyze(text)
    psum = partial_sum_allreduces(text)
    violations: List[Tuple[str, float, float]] = []
    for kind, cap in (max_counts or {}).items():
        got = facts["collectives"].get(kind, {}).get("count", 0.0)
        if got > cap:
            violations.append((f"{kind} count", got, float(cap)))
    if max_bytes is not None and facts["collective_bytes"] > max_bytes:
        violations.append(("collective bytes", facts["collective_bytes"],
                           float(max_bytes)))
    if max_partial_sum is not None and psum["count"] > max_partial_sum:
        violations.append(("partial-sum all-reduces", float(psum["count"]),
                           float(max_partial_sum)))
    return {
        "collectives": facts["collectives"],
        "collective_bytes": facts["collective_bytes"],
        "partial_sum": psum,
        "violations": violations,
    }

"""Static analysis of the serving system: the compiled-program auditor
(``hlo``, ``contract``, ``retrace``) and the source lint (``lint``).

Entry points:

* ``contract.audit_engine(engine)`` — lower + check every jitted step
  closure of a constructed ``ServeEngine`` against its serving contract.
* ``retrace.retrace_findings(engine)`` — compile-count guard after a
  served trace (each closure compiles exactly once).
* ``lint.lint_paths(roots)`` — AST rules over the source tree.
* ``benchmarks/audit.py`` — the CLI that runs all of it across the
  family × mode × placement matrix and writes ``results/audit.json``.

See ``docs/analysis.md`` for the invariant → checker → gate table.
"""
from repro.analysis.findings import Finding, format_findings, gating  # noqa: F401

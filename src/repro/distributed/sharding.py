"""Sharding policies: CASCADE column-parallel (paper-faithful) vs
Megatron-style row+column TP (baseline).

The paper's central distribution claim (Sections 2.2, 13.5): partial-sum
transfers dominate interconnect traffic in conventional distributed
inference; CASCADE eliminates them by making the **output-column dimension
the unit of parallelism** and keeping every reduction local. On a TPU mesh:

* ``cascade`` policy — every weight is sharded on its OUTPUT dim over
  ``model`` — including expert weights, whose unit of parallelism is the
  output column rather than the expert index. Activations are all-gathered
  (linear in d_model) between layers; **no all-reduce of partial sums
  exists anywhere in the graph**.
* ``megatron`` policy — the classic pairing: first matmul column-sharded,
  second matmul row-sharded, followed by an all-reduce of partial sums
  (quadratic-width accumulator traffic — what the paper abolishes); expert
  weights are expert-parallel (E over ``model``), the conventional MoE
  layout.

The dry-run roofline quantifies the collective-bytes difference between the
two policies for every (arch x shape) cell, and ``ServeEngine`` (see
``serve/engine.py``) places live serving params with these same policies —
the cascade decode step carries an executable zero-partial-sum-all-reduce
assertion (``benchmarks/hlo_analysis.partial_sum_allreduces``).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# modules whose 2D weight contracts on dim 0 and expands on dim 1
_COLUMN_MODULES = {
    "wq", "wk", "wv", "w_gate", "w_up", "w_in", "wa", "wx", "in_proj",
    "wq_a", "wq_b", "wkv_a", "wkv_b", "lm_head",
}
# modules whose OUTPUT returns to d_model (Megatron shards these on dim 0)
_ROW_MODULES = {"wo", "w_down", "w_out", "out_proj"}
_EXPERT_MODULES = {"wg", "wu", "wd"}


def _leading_nones(n: int) -> tuple:
    return (None,) * n


def spec_for_param(path: tuple[str, ...], leaf, policy: str, model_axis: str = "model",
                   tied_embed: bool = False):
    """PartitionSpec for one param leaf, by (module name, leaf name, ndim)."""
    names = [p for p in path]
    leaf_name = names[-1] if names else ""
    module = names[-2] if len(names) >= 2 else ""
    ndim = leaf.ndim

    def pad(spec: tuple) -> P:
        return P(*(_leading_nones(ndim - len(spec)) + spec))

    # experts: (.., E, K, N) / codes (.., E, K//2, N) / scale (.., E, G, N).
    # cascade shards the OUTPUT column N (the paper's unit of parallelism —
    # every expert contraction stays local, combine is gather-only);
    # megatron keeps conventional expert parallelism (E over model).
    if module in _EXPERT_MODULES:
        if ndim >= 3:
            shard3 = ((model_axis, None, None) if policy == "megatron"
                      else (None, None, model_axis))
            return P(*(_leading_nones(ndim - 3) + shard3))
        return pad((None,))

    if leaf_name == "table":  # embedding (V, d)
        if policy == "megatron":
            return pad((model_axis, None))
        if tied_embed:
            # tied head: logits = x @ table.T contracts over d — a d-sharded
            # table would partial-sum the head matmul, so the table stays
            # replicated (memory for zero interconnect, the CASCADE trade)
            return pad((None, None))
        return pad((None, model_axis))

    if module == "router":
        return pad((None, None))

    is_linear = module in _COLUMN_MODULES or module in _ROW_MODULES
    if is_linear and leaf_name in ("w", "codes"):
        if policy == "megatron" and module in _ROW_MODULES:
            return pad((model_axis, None))
        return pad((None, model_axis))
    if is_linear and leaf_name == "scale":   # FP4 quant scales (G, N)
        if policy == "megatron" and module in _ROW_MODULES:
            return pad((None, None))
        return pad((None, model_axis))
    if is_linear and leaf_name == "b":
        if policy == "megatron" and module in _ROW_MODULES:
            return pad((None,))
        return pad((model_axis,))

    # norms, convs, gates, scalars: replicated
    return P(*_leading_nones(ndim))


def _path_names(path) -> tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(params_tree: Any, policy: str = "cascade", model_axis: str = "model",
                tied_embed: bool = False):
    """PartitionSpec tree mirroring ``params_tree`` (arrays or SDS leaves).

    ``tied_embed`` marks archs whose lm_head is the embedding transpose
    (mamba2, phi4): their table stays replicated under cascade so the tied
    head matmul never contracts over a sharded dim.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(_path_names(path), leaf, policy,
                                          model_axis, tied_embed),
        params_tree)


def filter_divisible(specs_tree: Any, shapes_tree: Any, mesh):
    """Drop mesh-axis names from dims the axis size does not divide.

    Smoke-sized serving shapes (and batch-1 staging caches) routinely have
    dims smaller than a mesh axis; replicating those leaves keeps placement
    well-defined without per-arch divisibility bookkeeping.
    """
    def axis_size(name) -> int:
        names = name if isinstance(name, tuple) else (name,)
        n = 1
        for a in names:
            n *= mesh.shape.get(a, 1)
        return n

    def fix(leaf, spec):
        parts = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        return P(*(name if name is not None and leaf.shape[i] % axis_size(name) == 0
                   else None
                   for i, name in enumerate(parts)))

    return jax.tree.map(fix, shapes_tree, specs_tree)


def named_shardings(mesh, specs_tree: Any):
    """PartitionSpec tree -> NamedSharding tree (device_put placement)."""
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_tree: Any, batch_axes=("pod", "data"), mesh=None):
    """Shard the leading batch dim of every input over the data axes (and the
    M-RoPE position stream's axis 1). Falls back to replication when the
    batch doesn't divide the axes (long_500k has batch 1)."""
    sizes = 1
    if mesh is not None:
        for a in batch_axes:
            if a in mesh.shape:
                sizes *= mesh.shape[a]
    axes = tuple(a for a in batch_axes if mesh is None or a in mesh.shape)

    def spec(path, leaf):
        names = _path_names(path)
        bdim = 1 if names and names[-1] == "positions" else 0  # (3, B, S)
        if leaf.shape[bdim] % max(sizes, 1) != 0:
            return P(*(None,) * leaf.ndim)
        out = [None] * leaf.ndim
        out[bdim] = axes if len(axes) > 1 else (axes[0] if axes else None)
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_specs(cache_tree: Any, mesh, model_axis: str = "model",
                batch_axes=("pod", "data")):
    """KV/state cache sharding: batch over data axes; heads (or head-like
    dims) over model where divisible. Cache layouts per family:
      attn   k/v: (L, B, T, Hkv, hd)     -> (None, data, None, model?, None)
      mla    c_kv: (L, B, T, lora)       -> (None, data, None, None)
      ssm    state: (L, B, H, P, N)      -> (None, data, model?, None, None)
      conv   (L, B, w-1, dim)            -> (None, data, None, model?)
    """
    model_size = mesh.shape.get(model_axis, 1)
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    data_size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    baxis = axes if len(axes) > 1 else (axes[0] if axes else None)

    def spec(path, leaf):
        names = _path_names(path)
        leaf_name = names[-1]
        # find batch dim: caches under stacked layers have shape (L, B, ...);
        # tail/dense (unstacked) have (B, ...)
        stacked = any(n in ("layers", "groups") for n in names)
        if leaf.ndim == 0 or leaf_name in ("pos", "slot_pos"):
            return P(*(None,) * leaf.ndim)
        bdim = 1 if stacked else 0
        out = [None] * leaf.ndim
        if leaf.shape[bdim] % max(data_size, 1) == 0 and data_size > 1:
            out[bdim] = baxis
        # shard a head-like dim over model when divisible; else shard the
        # cache TIME dim (sequence-sharded KV: decode attention contracts
        # over T per-shard and psums a tiny (B,H,1,dv) result — trades a
        # micro-collective for model_size-x less cache traffic/memory)
        if leaf_name in ("k", "v"):
            hdim = leaf.ndim - 2
            tdim = leaf.ndim - 3
            if leaf.shape[hdim] % model_size == 0:
                out[hdim] = model_axis
            elif leaf.shape[tdim] % model_size == 0:
                out[tdim] = model_axis
        elif leaf_name == "c_kv" and leaf.shape[-2] % model_size == 0:
            out[-2] = model_axis          # MLA latent cache: (L, B, T, lora)
        elif leaf_name == "k_rope" and leaf.shape[-2] % model_size == 0:
            out[-2] = model_axis
        elif leaf_name == "state" and leaf.ndim >= bdim + 4:
            hdim = bdim + 1
            if leaf.shape[hdim] % model_size == 0:
                out[hdim] = model_axis
        elif leaf_name in ("conv", "h"):
            if leaf.shape[-1] % model_size == 0:
                out[-1] = model_axis
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def add_data_dim(specs_tree, shapes_tree, mesh, batch_axes=("pod", "data")):
    """ZeRO-style: additionally shard each leaf over the data axes on its
    first unsharded, divisible dim. Applied to optimizer moments (ZeRO-1)
    and optionally to the params themselves (FSDP / ZeRO-3)."""
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    daxis = axes if len(axes) > 1 else (axes[0] if axes else None)

    def upd(spec, leaf):
        if size <= 1 or leaf.ndim == 0:
            return spec
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        for i in range(leaf.ndim):
            if parts[i] is None and leaf.shape[i] % size == 0:
                parts[i] = daxis
                return P(*parts)
        return spec

    return jax.tree.map(upd, specs_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation sharding discipline (set by launchers; no-op on bare CPU tests)
# ---------------------------------------------------------------------------

_ACT_POLICY: dict | None = None


def set_activation_policy(mesh, policy: str = "cascade",
                          batch_axes=("pod", "data"), seq_axis=None,
                          moe_ep: bool = False):
    """Install the activation-constraint discipline used inside model code.

    cascade:  residual stream (B, S, d) constrained to (batch, None, None) —
              features replicated over ``model`` so every matmul lowers to
              all-gather-of-activations + local contraction; NO partial-sum
              all-reduce can appear in a forward graph (the paper's CASCADE
              invariant, Section 13.5).
    seqpar:   residual constrained to (batch, model, None) — sequence
              parallelism between blocks (Korthikanti et al.); gathers move
              S/model-sized shards and reductions become reduce-scatters.
    none:     leave GSPMD propagation alone (measured baseline).
    """
    global _ACT_POLICY
    if policy == "fulldp":  # pure data parallelism: batch over every axis
        batch_axes = ("pod", "data", "model")
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    baxis = axes if len(axes) > 1 else (axes[0] if axes else None)
    _ACT_POLICY = {"policy": policy, "batch": baxis,
                   "seq": "model" if policy == "seqpar" else None,
                   "mesh": mesh, "batch_axes": batch_axes, "moe_ep": moe_ep}


def get_activation_policy():
    return _ACT_POLICY


def clear_activation_policy():
    global _ACT_POLICY
    _ACT_POLICY = None


def constrain_matmul_input(x):
    """CASCADE discipline for every linear input: features fully replicated
    over ``model`` (the paper's activation *broadcast*, Section 13.4) so the
    contraction stays local and no partial-sum all-reduce is emitted.
    Active only under the 'cascade' activation policy."""
    if _ACT_POLICY is None or _ACT_POLICY["policy"] not in ("cascade", "fulldp"):
        return x
    if x.ndim < 2:
        return x
    spec = P(_ACT_POLICY["batch"], *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_attn_queries(x, seq_dim: int = 1):
    """Shard the attention *query-position* dim over ``model`` (active under
    any installed policy). Heads often don't divide the model axis (GQA kv=8
    on a 16-way axis); sharding q-positions keeps every contraction local —
    zero partial-sum all-reduce — at the cost of gathering K/V once per
    layer. This is the CASCADE-consistent attention layout: row-blocks of
    activations distributed, weights/columns local."""
    if _ACT_POLICY is None or _ACT_POLICY["policy"] in ("none", "fulldp"):
        return x
    if x.ndim <= seq_dim or x.shape[seq_dim] % 16 != 0:
        return x
    spec = [None] * x.ndim
    spec[0] = _ACT_POLICY["batch"]
    spec[seq_dim] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_expert_buffer(x):
    """Constrain an (E, C, d) MoE dispatch/expert buffer.

    Under ``cascade`` the buffer is REPLICATED (the paper's activation
    broadcast, Section 13.4): expert weights are column-sharded, so every
    expert contraction is local and the combine is gather-only. NOTE the
    train-path caveat: building this buffer from data-SHARDED train tokens
    still cross-shard-combines at the scatter — the documented MoE-dispatch
    exception to the zero-partial-sum claim (an E-sharded buffer constraint
    did not avoid it either, see models/moe_shardmap.py, which exists to
    kill it; it would also now conflict with the column-sharded weights).
    Serving paths replicate the tokens BEFORE the scatter (moe_ffn_apply
    no_drop), so the invariant holds exactly where it is asserted. Other
    policies keep expert parallelism (E over ``model``): the scatter from
    data-sharded tokens then lowers to an all-to-all (tokens move once)
    instead of an all-reduce of the whole buffer across data shards."""
    if _ACT_POLICY is None or _ACT_POLICY["policy"] == "none":
        return x
    if _ACT_POLICY["policy"] in ("cascade", "fulldp"):
        return constrain_replicated(x)
    if x.ndim != 3 or x.shape[0] % 16 != 0:
        return x
    return jax.lax.with_sharding_constraint(x, P("model", None, None))


def constrain_replicated(x):
    """Fully replicate an activation under the cascade policy (the CASCADE
    activation broadcast): inputs to contractions that do NOT go through
    ``cascade.linear_apply`` — attention q/k/v against a cache, the MoE
    dispatch scatter at serving batch sizes, and every logits row that
    feeds sampling (the decode row AND the speculative verify pass's full
    (B, K+1, V) row block: top-k / softmax / the Gumbel add / the
    rejection-resampling acceptance over a vocab-sharded row would all
    lower to partial-sum all-reduces) — are pinned replicated so no
    partial-sum all-reduce can be emitted downstream. No-op without an
    installed cascade/fulldp policy (CPU tests, megatron baseline)."""
    if _ACT_POLICY is None or _ACT_POLICY["policy"] not in ("cascade", "fulldp"):
        return x
    return jax.lax.with_sharding_constraint(x, P(*(None,) * x.ndim))


def constrain_residual(x):
    """Apply the installed activation constraint to a (B, S, d) residual."""
    if _ACT_POLICY is None or _ACT_POLICY["policy"] == "none":
        return x
    seq = _ACT_POLICY["seq"]
    if seq is not None and x.ndim >= 2 and x.shape[1] % 16 == 0:
        spec = P(_ACT_POLICY["batch"], seq, *(None,) * (x.ndim - 2))
    else:
        spec = P(_ACT_POLICY["batch"], *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, spec)

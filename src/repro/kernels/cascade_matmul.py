"""CASCADE FP4 matmul — Pallas TPU kernel.

TPU-native adaptation of the paper's CASCADE array (Sections 10, 13):

* Weights live in HBM as **packed FP4 E2M1** (two codes per uint8, packed
  along the contraction dim) — this is the memory-roofline win the paper's
  HBM-balance analysis (Table 10) depends on (4 bits/weight).
* Each grid step stages an FP4 weight tile into VMEM, decodes it
  arithmetically (no gathers — sign/exponent/mantissa bit math, VPU friendly)
  and feeds the MXU in bf16 with an fp32 VMEM scratch accumulator.
  The HBM->VMEM double-buffered pipeline is the TPU analogue of the paper's
  HILT staging hierarchy; the K-grid accumulation revisits are the analogue
  of the CASCADE inter-array partial-sum latches: partial sums never leave
  the chip (grid dims are ("parallel", "parallel", "arbitrary")).
* Per-(K-group, column) scales are applied at the accumulation epilogue, and
  the bias is added at the column output — mirroring the paper's
  "biases are added in the output sums HILT" (Section 2.2).

Block shapes default to (bm, bn, bk) = (128, 256, 512): MXU-aligned
(multiples of 128); VMEM footprint per step =
  x tile 128*512*2B + packed w tile 256*256*1B + decoded 512*256*2B
  + acc 128*256*4B ~= 0.58 MB  << 16 MB VMEM (room for double buffering).

Two numeric modes (``exact_dequant``):

* **fast** (default, the TPU production path) — decode FP4 to bf16, feed the
  MXU in bf16, apply the group scale to the (bm, bn) *product* once per
  K-block (cheaper than scaling the (bk, bn) weight tile).
* **exact** — decode to f32, scale the *weight tile* elementwise, cast to
  ``compute_dtype`` and dot. With a single-block grid this performs literally
  the same dequantize -> dot -> bias operations as the jnp serving path
  (``quant.dequantize_weight`` + ``jnp.dot``), so interpret-mode results are
  bit-identical to it — the token-exactness contract of the fused serving
  path (``ServeConfig.fused``). In exact mode the scales input is pre-
  expanded to per-row ``(K, N)`` so arbitrary group sizes broadcast exactly
  like the jnp path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional off-TPU
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _decode_fp4_block(codes: jax.Array, dtype) -> jax.Array:
    """Arithmetic FP4 E2M1 decode (no table gather)."""
    c = codes.astype(jnp.int32)
    s = (c >> 3) & 1
    e = (c >> 1) & 3
    m = (c & 1).astype(jnp.float32)
    mag = jnp.where(e == 0, 0.5 * m, (1.0 + 0.5 * m) * jnp.exp2(e.astype(jnp.float32) - 1.0))
    return jnp.where(s == 1, -mag, mag).astype(dtype)


def _kernel(x_ref, wq_ref, s_ref, b_ref, o_ref, acc_ref, *, nk: int, out_dtype,
            compute_dtype, exact_dequant: bool, has_bias: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = wq_ref[...]  # (bk//2, bn) uint8
    lo = packed & jnp.uint8(0xF)
    hi = (packed >> 4) & jnp.uint8(0xF)
    bk2, bn = packed.shape
    codes = jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)
    if exact_dequant:
        # per-row (bk, bn) scales: the same elementwise dequant multiply as
        # quant.dequantize_weight, then the dot in compute_dtype — with a
        # single-block grid this is bit-identical to the jnp serving path
        w = (_decode_fp4_block(codes, jnp.float32) * s_ref[...]).astype(compute_dtype)
        x = x_ref[...].astype(compute_dtype)
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    else:
        w = _decode_fp4_block(codes, jnp.bfloat16)  # unscaled FP4 values
        x = x_ref[...].astype(jnp.bfloat16)
        prod = jnp.dot(x, w, preferred_element_type=jnp.float32)  # (bm, bn) fp32
        # scale is constant across the K-block (group_size % bk == 0), applied
        # to the (bm, bn) product: cheaper than scaling the (bk, bn) weight tile.
        acc_ref[...] += prod * s_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if has_bias:
            acc = acc + b_ref[...].astype(jnp.float32)
        o_ref[...] = acc.astype(out_dtype)


def cascade_matmul_pallas(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    bias: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    compute_dtype=jnp.bfloat16,
    exact_dequant: bool = False,
    has_bias: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K) bf16/f32; packed: (K//2, N) uint8; bias: (1, N) f32.
    Returns (M, N) out_dtype.

    ``scales``: fast mode takes (G, N) f32 with group_size = K // G and
    group_size % block_k == 0; exact mode (``exact_dequant=True``) takes
    per-row (K, N) f32 (pre-expanded by the caller) so the weight tile is
    dequantized exactly like ``quant.dequantize_weight`` before the dot.
    ``has_bias=False`` skips the bias add entirely (matching the jnp path's
    conditional add bit-for-bit; ``bias`` is still passed as zeros to keep
    the call signature static)."""
    m, kdim = x.shape
    n = packed.shape[1]
    assert packed.shape[0] * 2 == kdim
    assert m % block_m == 0 and n % block_n == 0 and kdim % block_k == 0, (
        f"unpadded dims ({m},{n},{kdim}) vs blocks ({block_m},{block_n},{block_k})")
    if exact_dequant:
        assert scales.shape == (kdim, n), (
            f"exact mode needs per-row scales (K, N); got {scales.shape}")
        scale_spec = pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j))
    else:
        group_size = kdim // scales.shape[0]
        assert group_size % block_k == 0, (
            f"group_size {group_size} must be a multiple of block_k {block_k}")
        scale_spec = pl.BlockSpec(
            (1, block_n), lambda i, j, k, gs=group_size, bk=block_k: (k * bk // gs, j))
    nk = kdim // block_k

    grid = (m // block_m, n // block_n, nk)

    kernel = functools.partial(_kernel, nk=nk, out_dtype=out_dtype,
                               compute_dtype=compute_dtype,
                               exact_dequant=exact_dequant, has_bias=has_bias)
    kwargs = {}
    if pltpu is not None and not interpret:
        params_cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k // 2, block_n), lambda i, j, k: (k, j)),
            scale_spec,
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)] if pltpu is not None else [],
        interpret=interpret,
        **kwargs,
    )(x, packed, scales, bias)

"""Blocked (flash) attention — Pallas TPU kernels.

Two kernels:

* ``flash_attention_pallas`` — the prefill hot-spot: streaming-softmax
  self-attention with GQA head mapping. Grid is (batch*q_heads, q_blocks,
  k_blocks) with the running max / denominator / accumulator held in VMEM
  scratch across the (sequential) k dimension — the same "partial results
  never leave the chip" dataflow CASCADE uses for matmul columns, applied
  to attention rows.
* ``decode_attention_pallas`` — the serving decode step: ONE query token per
  batch row against a stacked (B, T, Hkv, D) cache with a per-slot validity
  mask (ring buffers and full caches both reduce to "mask says which of the
  T slots hold real keys"). Streams over T blocks when compiled; the
  ``exact`` mode instead runs one program over the whole batch performing
  literally the jnp decode math (mask -> softmax -> value einsums), which
  is what makes the fused serving path token-exact with the XLA path in
  interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, nk, block_q, block_k):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal: block fully masked iff first q row < first k col.

    @pl.when((not causal) or (iq * block_q + block_q - 1 >= ik * block_k))
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    nq, nk = s // block_q, s // block_k

    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)

    def kv_index(bh, iq, ik):
        return ((bh // hq) * hkv + (bh % hq) // group, ik, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, nk=nk, block_q=block_q, block_k=block_k)
    kwargs = {}
    if pltpu is not None and not interpret:
        params_cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ] if pltpu is not None else [],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, d)


def _decode_exact_kernel(q_ref, k_ref, v_ref, mk_ref, o_ref, *, scale, hkv: int):
    # one program over the FULL batched shapes, performing verbatim the jnp
    # decode math of models.layers.attn_apply (same einsum/batched-dot
    # lowerings, same masked-softmax ordering) — interpret-mode results are
    # bit-identical to the XLA path, which is the fused serving path's
    # token-exactness contract
    b, hq, d = q_ref.shape
    g = hq // hkv
    qd = q_ref[...].astype(jnp.float32).reshape(b, 1, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qd,
                        k_ref[...].astype(jnp.float32)) * scale
    logits = jnp.where((mk_ref[...] != 0)[:, None, None, None, :],
                       logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p, v_ref[...].astype(jnp.float32))
    o_ref[...] = o.reshape(b, hq, d)


def _decode_kernel(q_ref, k_ref, v_ref, mk_ref, o_ref, mx_ref, l_ref, acc_ref,
                   *, scale, nt: int):
    it = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)            # (1, d)
    k = k_ref[0].astype(jnp.float32)            # (bt, d)
    v = v_ref[0].astype(jnp.float32)
    live = mk_ref[...] != 0                     # (1, bt)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, bt)
    s = jnp.where(live, s, _NEG_INF)

    @pl.when(it == 0)
    def _init():
        mx_ref[...] = jnp.full_like(mx_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m_prev = mx_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # a fully-masked block leaves m_new at -inf and exp(s - m_new) at
    # exp(0) = 1; zeroing p under the mask keeps the degenerate block from
    # polluting the denominator (decode always has >= 1 live slot overall)
    p = jnp.where(live, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    mx_ref[...] = m_new

    @pl.when(it == nt - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array,
    *,
    scale: float | None = None,
    block_t: int = 512,
    exact: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """One-token attention against a stacked KV cache.

    q: (B, Hq, D) — the decode step's single query token per row;
    k/v: (B, T, Hkv, D) — the cache layout ``layers.attn_apply`` holds;
    mask: (B, T), nonzero where the slot holds a real key (full caches:
    position <= current; ring buffers: in-window slots). GQA via head-group
    broadcast. Returns (B, Hq, D) f32.

    ``exact=True`` runs ONE program over the whole batch performing verbatim
    the jnp decode einsum math — interpret-mode results are then
    bit-identical to the jnp decode attention. The default streams over T
    blocks with running max/denominator scratch (the compiled TPU path).
    """
    b, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    if exact:
        # one program over the whole batch: replicates the jnp decode math
        # verbatim (bit-identical in interpret mode); whole-array refs, no
        # blocking — splitting per (b, h) changes the batched-dot lowering
        # and costs a few ULPs on some shapes
        return pl.pallas_call(
            functools.partial(_decode_exact_kernel, scale=scale, hkv=hkv),
            out_shape=jax.ShapeDtypeStruct((b, hq, d), jnp.float32),
            interpret=interpret,
        )(q, k, v, mask.astype(jnp.int32))
    block_t = min(block_t, t)
    rem = (-t) % block_t
    if rem:  # pad T to a block multiple; padded slots are masked out
        k = jnp.pad(k, ((0, 0), (0, rem), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, rem), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, rem)))
        t += rem
    nt = t // block_t

    qf = q.reshape(b * hq, 1, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    mk = mask.astype(jnp.int32)

    def kv_index(bh, it):
        return ((bh // hq) * hkv + (bh % hq) // group, it, 0)

    kernel = functools.partial(_decode_kernel, scale=scale, nt=nt)
    kwargs = {}
    if pltpu is not None and not interpret:
        params_cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nt),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bh, it: (bh, 0, 0)),
            pl.BlockSpec((1, block_t, d), kv_index),
            pl.BlockSpec((1, block_t, d), kv_index),
            pl.BlockSpec((1, block_t), lambda bh, it: (bh // hq, it)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, it: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ] if pltpu is not None else [],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf, mk)
    return out.reshape(b, hq, d)

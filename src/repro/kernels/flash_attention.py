"""Blocked (flash) attention — Pallas TPU kernel for the prefill hot-spot.

Streaming-softmax attention with GQA head mapping. Grid is
(batch*q_heads, q_blocks, k_blocks) with the running max / denominator /
accumulator held in VMEM scratch across the (sequential) k dimension —
the same "partial results never leave the chip" dataflow CASCADE uses for
matmul columns, applied to attention rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, nk, block_q, block_k):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal: block fully masked iff first q row < first k col.

    @pl.when((not causal) or (iq * block_q + block_q - 1 >= ik * block_k))
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    nq, nk = s // block_q, s // block_k

    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)

    def kv_index(bh, iq, ik):
        return ((bh // hq) * hkv + (bh % hq) // group, ik, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, nk=nk, block_q=block_q, block_k=block_k)
    kwargs = {}
    if pltpu is not None and not interpret:
        params_cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ] if pltpu is not None else [],
        interpret=interpret,
        **kwargs,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, d)

"""Jit'd public wrappers for the Pallas kernels (padding, defaults, backend
dispatch). ``interpret=True`` is selected automatically off-TPU so the same
call sites work in CI (CPU) and production (TPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import cascade_matmul as _cm
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret",
                     "exact_dequant"),
)
def cascade_matmul(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    bias: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
    exact_dequant: bool | None = None,
) -> jax.Array:
    """FP4-packed weight matmul: x (.., K) @ Wq (K, N) -> (.., N).

    Leading dims of x are flattened to M and padded to block_m; K and N must
    already be block-aligned (true for every assigned architecture dim).
    Odd-K weights (quantize_weight zero-row pad-to-pack) are handled by
    padding the activations with a matching zero column.

    ``exact_dequant`` defaults to the resolved ``interpret`` value: compiled
    (TPU) runs the fast bf16-MXU kernel; interpret mode (CPU/CI) runs a
    single-block grid whose kernel body performs the same dequantize ->
    dot -> bias operations as the jnp serving path on the same shapes, so
    results are bit-identical to ``cascade.linear_apply``'s XLA branch —
    the fused serving path's token-exactness contract.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if exact_dequant is None:
        exact_dequant = interpret
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    n = packed.shape[1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    if packed.shape[0] * 2 == kdim + 1:
        # odd-K weights carry quantize_weight's zero pad row: give the
        # activations a matching zero column (contributes nothing to the dot)
        x2 = jnp.pad(x2, ((0, 0), (0, 1)))
        kdim += 1
    bias2 = jnp.zeros((1, n), jnp.float32) if bias is None else bias.reshape(1, n).astype(jnp.float32)
    if exact_dequant:
        assert interpret, "exact_dequant is the interpret-mode parity path"
        # single-block grid on the unpadded operands (interpret mode needs no
        # block alignment); per-row scales broadcast exactly like the jnp
        # dequant's group reshape
        group = kdim // scales.shape[0]
        s_full = jnp.repeat(scales, group, axis=0)          # (K, N)
        out = _cm.cascade_matmul_pallas(
            x2, packed, s_full, bias2,
            block_m=x2.shape[0], block_n=n, block_k=kdim,
            out_dtype=out_dtype, compute_dtype=out_dtype,
            exact_dequant=True, has_bias=bias is not None, interpret=True,
        )
        return out.reshape(*lead, n)
    x2 = _pad_to(x2, 0, block_m)
    # shrink blocks if dims are small (smoke configs)
    bm = min(block_m, x2.shape[0])
    bn = block_n if n % block_n == 0 else n
    bk = block_k if (kdim % block_k == 0 and (kdim // scales.shape[0]) % block_k == 0) else kdim // scales.shape[0]
    out = _cm.cascade_matmul_pallas(
        x2, packed, scales, bias2,
        block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m].reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked attention, GQA-aware. q: (B,Hq,S,D), k/v: (B,Hkv,S,D)."""
    if interpret is None:
        interpret = not _on_tpu()
    return _fa.flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("scale", "block_t", "interpret"))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    *,
    scale: float | None = None,
    block_t: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode-step attention on a stacked cache. q: (B, Hq, D) — one query
    token per slot; k/v: (B, T, Hkv, D) cache buffers; valid: (B, T) nonzero
    where the slot holds a real key. Returns (B, Hq, D) f32.

    Interpret mode (CPU/CI) runs the exact single-block kernel —
    bit-identical to the jnp decode attention in ``layers.attn_apply``;
    compiled (TPU) streams over T blocks with running-softmax scratch."""
    if interpret is None:
        interpret = not _on_tpu()
    return _fa.decode_attention_pallas(
        q, k, v, valid, scale=scale, block_t=block_t,
        exact=interpret, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 64, interpret: bool | None = None):
    """Per-head SSD recurrence (inputs pre-broadcast per head). (BH,S,P)."""
    if interpret is None:
        interpret = not _on_tpu()
    return _ssd.ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_decode(x, dt, A, B, C, D, state, *, interpret: bool | None = None):
    """One-token SSD recurrence on the stacked decode cache — the serving
    decode step routed through the scan kernel at ``s = chunk = 1`` with
    the slot states as the carried initial state.

    Shapes mirror ``models.ssm.ssd_decode_step``: x (B,1,H,P); dt (B,1,H)
    (post-softplus, f32); A (H,); B/C (B,1,G,N); D (H,); state (B,H,P,N)
    f32. Returns (y (B,1,H,P) in x.dtype, new_state f32). The kernel body
    performs the identical decay/update/readout arithmetic on the identical
    f32 operands, so interpret mode (CPU/CI) is bit-exact with the jnp
    recurrence — the fused serving path's token-exactness contract.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, _, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hb = h // g
    xf = x[:, 0].astype(jnp.float32).reshape(b * h, 1, p)
    dtf = dt[:, 0].astype(jnp.float32).reshape(b * h, 1)
    Bh = jnp.repeat(B[:, 0].astype(jnp.float32), hb, axis=1).reshape(b * h, 1, n)
    Ch = jnp.repeat(C[:, 0].astype(jnp.float32), hb, axis=1).reshape(b * h, 1, n)
    Af = jnp.broadcast_to(A.astype(jnp.float32), (b, h)).reshape(b * h)
    Df = jnp.broadcast_to(D.astype(jnp.float32), (b, h)).reshape(b * h)
    y, fin = _ssd.ssd_scan_pallas(
        xf, dtf, Af, Bh, Ch, Df, chunk=1, interpret=interpret,
        initial_state=state.reshape(b * h, p, n), return_final_state=True)
    return (y.reshape(b, h, p)[:, None].astype(x.dtype),
            fin.reshape(b, h, p, n))


# Re-exported oracles (tests and low-stakes call sites)
cascade_matmul_ref = _ref.cascade_matmul_ref
flash_attention_ref = _ref.flash_attention_ref
decode_attention_ref = _ref.decode_attention_ref
ssd_scan_ref = _ref.ssd_scan_ref

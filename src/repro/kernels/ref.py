"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def cascade_matmul_ref(
    x: jax.Array,
    packed: jax.Array,
    scales: jax.Array,
    bias: jax.Array | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Oracle for the fast (fp32-accumulating) CASCADE matmul.

    x: (M, K) activations; packed: (K//2, N) FP4 codes; scales: (G, N).
    Dequantizes to f32 and matmuls with f32 accumulation. Odd-K weights
    (``quant.quantize_weight`` zero-row pad-to-pack) are matched by padding
    the activations with a zero column, like ``ops.cascade_matmul``.
    """
    w = quant.dequantize_weight(packed, scales, dtype=jnp.float32)
    if w.shape[0] == x.shape[-1] + 1:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, 1)])
    out = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(out_dtype)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True, scale: float | None = None
) -> jax.Array:
    """Oracle attention. q: (B, Hq, S, D), k/v: (B, Hkv, S, D). GQA via head
    group broadcast. Returns (B, Hq, S, D) in q.dtype."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, group, s, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qf, kf) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, vf)
    return out.reshape(b, hq, s, d).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, valid: jax.Array,
    scale: float | None = None,
) -> jax.Array:
    """Oracle for the decode-attention kernel: one query token per batch row
    against a stacked cache. q: (B, Hq, D); k/v: (B, T, Hkv, D); valid:
    (B, T) nonzero where the slot holds a real key. The SAME masked-softmax
    math as the jnp decode path in ``models.layers.attn_apply`` (mask via
    ``where`` at -1e30, softmax, value contraction). Returns (B, Hq, D) f32.
    """
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qd = q.astype(jnp.float32).reshape(b, 1, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qd, k.astype(jnp.float32)) * scale
    logits = jnp.where((valid != 0)[:, None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d)


def ssd_scan_ref(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array, D: jax.Array | None = None
) -> jax.Array:
    """Oracle Mamba-2 SSD (state-space dual) scan, sequential recurrence.

    Shapes (single batch element handled via vmap by callers):
      x:  (S, H, P)    inputs per head
      dt: (S, H)       softplus'd step sizes (>0)
      A:  (H,)         negative scalar per head (A = -exp(a_log))
      B:  (S, G, N)    input projections (G state groups, broadcast over H//G)
      C:  (S, G, N)    output projections
      D:  (H,) or None skip connection
    Returns y: (S, H, P).
    """
    s, h, p = x.shape
    g, n = B.shape[1], B.shape[2]
    heads_per_group = h // g

    def step(state, inputs):
        xt, dtt, Bt, Ct = inputs  # (H,P), (H,), (G,N), (G,N)
        Bh = jnp.repeat(Bt, heads_per_group, axis=0)  # (H, N)
        Ch = jnp.repeat(Ct, heads_per_group, axis=0)
        decay = jnp.exp(dtt * A)  # (H,)
        state = state * decay[:, None, None] + (dtt[:, None] * xt)[..., None] * Bh[:, None, :]
        y = jnp.einsum("hpn,hn->hp", state, Ch)
        return state, y

    state0 = jnp.zeros((h, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        state0,
        (x.astype(jnp.float32), dt.astype(jnp.float32), B.astype(jnp.float32), C.astype(jnp.float32)),
    )
    if D is not None:
        ys = ys + D[None, :, None] * x.astype(jnp.float32)
    return ys.astype(x.dtype)

"""Mamba-2 SSD scan — Pallas TPU kernel.

Grid (batch*heads, n_chunks): the chunk dimension is sequential ("arbitrary")
and the (P, N) SSM state lives in VMEM scratch across chunk iterations — the
same carry-stays-on-chip dataflow CASCADE uses for partial sums. Within a
chunk the recurrence runs as a fori_loop over the chunk's steps on VMEM
tiles (HBM->VMEM staging via BlockSpec = the HILT analogue).

Inputs are pre-broadcast per head (callers expand B/C groups):
  x:  (BH, S, P)   dt: (BH, S)   A: (BH,)   B, C: (BH, S, N)   D: (BH,)
Output y: (BH, S, P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0]
    dskip = d_ref[0]
    x = x_ref[0].astype(jnp.float32)      # (q, P)
    dt = dt_ref[0].astype(jnp.float32)    # (q,)
    bb = b_ref[0].astype(jnp.float32)     # (q, N)
    cc = c_ref[0].astype(jnp.float32)     # (q, N)

    def step(i, carry):
        state, ys = carry
        decay = jnp.exp(dt[i] * a)
        state = state * decay + (dt[i] * x[i])[:, None] * bb[i][None, :]   # (P,N)
        y = state @ cc[i] + dskip * x[i]                                    # (P,)
        ys = jax.lax.dynamic_update_slice(ys, y[None], (i, 0))
        return state, ys

    state0 = state_ref[...]
    ys0 = jnp.zeros((chunk, x.shape[-1]), jnp.float32)
    state, ys = jax.lax.fori_loop(0, chunk, step, (state0, ys0))
    state_ref[...] = state
    y_ref[0] = ys.astype(y_ref.dtype)


def _kernel_carry(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, s0_ref,
                  y_ref, out_s_ref, state_ref, *, chunk):
    """The scan kernel with a caller-supplied initial state and the final
    state emitted as a second output — the serving-decode entry point (a
    decode step is this kernel at s = chunk = 1)."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    a = a_ref[0]
    dskip = d_ref[0]
    x = x_ref[0].astype(jnp.float32)      # (q, P)
    dt = dt_ref[0].astype(jnp.float32)    # (q,)
    bb = b_ref[0].astype(jnp.float32)     # (q, N)
    cc = c_ref[0].astype(jnp.float32)     # (q, N)

    def step(i, carry):
        state, ys = carry
        decay = jnp.exp(dt[i] * a)
        state = state * decay + (dt[i] * x[i])[:, None] * bb[i][None, :]   # (P,N)
        y = state @ cc[i] + dskip * x[i]                                    # (P,)
        ys = jax.lax.dynamic_update_slice(ys, y[None], (i, 0))
        return state, ys

    state0 = state_ref[...]
    ys0 = jnp.zeros((chunk, x.shape[-1]), jnp.float32)
    state, ys = jax.lax.fori_loop(0, chunk, step, (state0, ys0))
    state_ref[...] = state
    y_ref[0] = ys.astype(y_ref.dtype)
    # same (h, 0, 0) block every chunk: the last sequential write is the one
    # flushed back to HBM, i.e. the post-scan state
    out_s_ref[0] = state


def ssd_scan_pallas(x, dt, A, B, C, D, *, chunk: int = 64,
                    interpret: bool = False, initial_state=None,
                    return_final_state: bool = False):
    bh, s, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    kwargs = {}
    if pltpu is not None and not interpret:
        params_cls = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "arbitrary"))

    in_specs = [
        pl.BlockSpec((1, chunk, p), lambda h, c: (h, c, 0)),
        pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
        pl.BlockSpec((1,), lambda h, c: (h,)),
        pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
        pl.BlockSpec((1, chunk, n), lambda h, c: (h, c, 0)),
        pl.BlockSpec((1,), lambda h, c: (h,)),
    ]
    scratch = [pltpu.VMEM((p, n), jnp.float32)] if pltpu is not None else []
    if initial_state is None and not return_final_state:
        return pl.pallas_call(
            functools.partial(_kernel, chunk=chunk),
            grid=(bh, nc),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, chunk, p), lambda h, c: (h, c, 0)),
            out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            scratch_shapes=scratch,
            interpret=interpret,
            **kwargs,
        )(x, dt, A, B, C, D)
    s0 = (jnp.zeros((bh, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    y, fin = pl.pallas_call(
        functools.partial(_kernel_carry, chunk=chunk),
        grid=(bh, nc),
        in_specs=in_specs + [pl.BlockSpec((1, p, n), lambda h, c: (h, 0, 0))],
        out_specs=[pl.BlockSpec((1, chunk, p), lambda h, c: (h, c, 0)),
                   pl.BlockSpec((1, p, n), lambda h, c: (h, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((bh, s, p), x.dtype),
                   jax.ShapeDtypeStruct((bh, p, n), jnp.float32)],
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(x, dt, A, B, C, D, s0)
    return (y, fin) if return_final_state else y

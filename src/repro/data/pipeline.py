"""Deterministic synthetic data pipeline.

A tokenizer-free corpus generator with realistic statistics (Zipfian unigram
over the arch's vocab + short-range Markov structure so the LM loss actually
has learnable signal), packed into fixed-length sequences, sharded by host.
Deterministic in (seed, step) so a restarted job resumes mid-epoch exactly
— the property the checkpoint/restart path relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    zipf_a: float = 1.2
    markov_order: int = 1


class SyntheticCorpus:
    """Zipf-Markov token stream. ``batch_at(step)`` is a pure function of
    (config, step), which makes data-parallel sharding and elastic restarts
    trivial: any host can regenerate any shard of any step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self.unigram = ranks ** (-cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # sparse Markov kernel: each token prefers a small successor set
        self.succ = rng.integers(0, cfg.vocab, size=(cfg.vocab, 4))

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4_096 + cfg.host_id)
        b, s = self.local_batch, cfg.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(cfg.vocab, size=b, p=self.unigram)
        mix = rng.random((b, s))
        jumps = rng.choice(cfg.vocab, size=(b, s), p=self.unigram)
        picks = rng.integers(0, 4, size=(b, s))
        for t in range(s):
            markov = self.succ[toks[:, t], picks[:, t]]
            toks[:, t + 1] = np.where(mix[:, t] < 0.75, markov, jumps[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

"""Shared neural-net layers for all assigned architectures.

Functional style: ``*_init(key, ...) -> params`` and ``*_apply(params, x, ...)``.
Every dense projection goes through :mod:`repro.core.cascade` so the paper's
FP4 serving format / QAT / column-parallel distribution apply uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import cascade
from repro.core.cascade import CascadeConfig
from repro.distributed.sharding import constrain_attn_queries, constrain_matmul_input
from repro.models import cache_utils


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d: int, norm_type: str = "rmsnorm") -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(params: dict, x: jax.Array, norm_type: str = "rmsnorm", eps: float = 1e-6) -> jax.Array:
    # CASCADE: norms sit at the between-layers boundary where activations
    # are gathered (paper Section 13.4) — pin features replicated so the
    # mean/variance reduction is local. Without this, GSPMD can let a
    # column-sharded branch output win the residual-add sharding and the
    # feature reduction becomes a (scalar, but nonzero) partial-sum
    # all-reduce. No-op without an installed cascade policy.
    x = constrain_matmul_input(x)
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (incl. partial-rotary and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0) -> jax.Array:
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32. Rotates the first
    2*len(inv_freq) channels, passes the rest through (partial rotary)."""
    rot2 = inv_freq.shape[0]
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (B, S, r/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., : 2 * rot2], x[..., 2 * rot2:]
    x1, x2 = x_rot[..., :rot2], x_rot[..., rot2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def apply_mrope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array,
                sections: tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) for (t, h, w); the
    inv_freq bands are split into ``sections`` (in half-dim units), each band
    rotated by its own position stream (arXiv:2409.12191)."""
    rot2 = inv_freq.shape[0]
    ang_all = positions[..., None].astype(jnp.float32) * inv_freq  # (3, B, S, r/2)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[i % 3, :, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, r/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., : 2 * rot2], x[..., 2 * rot2:]
    x1, x2 = x_rot[..., :rot2], x_rot[..., rot2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# per-slot cache primitives (continuous batching)
# ---------------------------------------------------------------------------

def pos_rows(pos: jax.Array, b: int) -> jax.Array:
    """Normalize a cache position to per-row shape (B,) int32.

    Caches written by this module carry one position per batch row so a
    stacked slot grid can hold streams of different lengths (continuous
    batching); a legacy scalar position is broadcast.
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    return pos


def pos_slots(slot_pos: jax.Array, b: int, t: int) -> jax.Array:
    """Normalize a ring-buffer slot-position table to per-row shape (B, T)."""
    slot_pos = jnp.asarray(slot_pos, jnp.int32)
    if slot_pos.ndim == 1:
        slot_pos = jnp.broadcast_to(slot_pos, (b, t))
    return slot_pos


def update_rows(buf: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """Write ``new[i]`` into ``buf[i]`` at row-specific index ``idx[i]``.

    buf: (B, T, ...); new: (B, s, ...); idx: (B,) per-row start along axis 1.
    """
    def one(bu, ne, i):
        start = (i,) + (0,) * (bu.ndim - 1)
        return jax.lax.dynamic_update_slice(bu, ne.astype(bu.dtype), start)

    return jax.vmap(one)(buf, new, idx)


# ---------------------------------------------------------------------------
# attention (GQA / MHA / local-window), full-seq and cached-decode paths
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    window: int = 0              # 0 = full causal; >0 = local attention window
    mrope_sections: tuple = ()   # Qwen2-VL
    softmax_scale: Optional[float] = None
    q_chunk: int = 0             # chunked attention for long prefill (0 = off)


def attn_init(key: jax.Array, cfg: AttnConfig, ccfg: CascadeConfig) -> dict:
    ks = jax.random.split(key, 4)
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": cascade.linear_init(ks[0], d, h * hd, ccfg, use_bias=cfg.qkv_bias),
        "wk": cascade.linear_init(ks[1], d, hk * hd, ccfg, use_bias=cfg.qkv_bias),
        "wv": cascade.linear_init(ks[2], d, hk * hd, ccfg, use_bias=cfg.qkv_bias),
        "wo": cascade.linear_init(ks[3], h * hd, d, ccfg),
    }


def _sdpa(q, k, v, mask, scale):
    """q: (B,S,H,D), k/v: (B,T,Hkv,D), mask: (S, T) bool or None."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    dv = v.shape[-1]  # may differ from q head dim (MLA)
    qf = constrain_attn_queries(q.astype(jnp.float32)).reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qf, k.astype(jnp.float32)) * scale
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, h, dv)


def _chunked_causal_sdpa(q, k, v, scale, q_chunk, window, q_offset=0):
    """Online-softmax attention over query chunks: memory O(q_chunk * T)
    instead of O(S * T). Pure jnp + lax.map — the XLA analogue of the flash
    kernel, used at lowering time for 32k prefill where the naive (S,S)
    logits tensor would be petabytes."""
    b, s, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    nq = s // q_chunk
    qc = q.reshape(b, nq, q_chunk, hkv, g, d)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)

    def one_chunk(i):
        qi = constrain_attn_queries(qc[:, i].astype(jnp.float32))  # (B, qc, Hkv, g, D)
        logits = jnp.einsum("bshgd,bthd->bhgst", qi, kf) * scale
        rows = q_offset + i * q_chunk + jnp.arange(q_chunk)
        cols = jnp.arange(t)
        m = rows[:, None] >= cols[None, :]
        if window > 0:
            m &= (rows[:, None] - cols[None, :]) < window
        logits = jnp.where(m[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgst,bthd->bshgd", p, vf).reshape(b, q_chunk, h, vf.shape[-1])

    out = jax.lax.map(one_chunk, jnp.arange(nq))  # (nq, B, qc, H, Dv)
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, v.shape[-1])


def attn_apply(
    params: dict,
    x: jax.Array,
    cfg: AttnConfig,
    ccfg: CascadeConfig,
    positions: jax.Array | None = None,
    cache: dict | None = None,
    mode: str = "full",
    max_len: int | None = None,
    n_valid=None,
) -> tuple[jax.Array, dict | None]:
    """Attention with four modes:

    * ``full``    — causal (optionally windowed) self-attention, no cache.
    * ``prefill`` — same compute as ``full`` but also RETURNS a KV cache
                    (ring-aligned for windowed archs) ready for decode.
    * ``decode``  — single new token (s==1) against the cache; the cache
                    buffer length equals the dry-run shape's seq_len for
                    full attention, or the window for local attention
                    (ring buffer, slot(p) = p %% window).
    * ``extend``  — append s tokens at each row's position (chunked prefill
                    into an existing cache). Rows may sit at different
                    positions: this is the continuous-batching admission
                    path. Only the first ``n_valid`` chunk tokens are real;
                    for full attention the pad K/V lands above the valid
                    region (mask-invalid, overwritten by the next write),
                    for ring buffers pad writes are dropped outright so
                    they can never clobber in-window entries.

    Cache positions are per-row (B,) so a stacked slot grid can hold streams
    of different lengths; legacy scalar positions are broadcast.
    """
    b, s, _ = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = cascade.linear_apply(params["wq"], x, ccfg).reshape(b, s, h, hd)
    k = cascade.linear_apply(params["wk"], x, ccfg).reshape(b, s, hk, hd)
    v = cascade.linear_apply(params["wv"], x, ccfg).reshape(b, s, hk, hd)

    if positions is None:
        if cache is not None:
            positions = pos_rows(cache["pos"], b)[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        else:
            positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    inv = rope_freqs(hd, cfg.rope_theta, cfg.rope_fraction)
    if cfg.mrope_sections:
        q = apply_mrope(q, positions, inv, cfg.mrope_sections)
        k = apply_mrope(k, positions, inv, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, inv)
        k = apply_rope(k, positions, inv)

    scale = cfg.softmax_scale or 1.0 / (hd ** 0.5)

    if mode in ("decode", "extend"):
        assert cache is not None
        assert mode == "extend" or s == 1
        # CASCADE serving layout: q/k/v keep batch over data with features
        # replicated over model. Without this pin, GSPMD may carry the
        # column-sharded projection output through the (b,s,H*hd)->(b,s,H,hd)
        # reshape onto the HEAD dim (n_kv_heads=1 ring caches force it onto
        # head_dim), and the score contraction over a sharded head_dim would
        # emit exactly the partial-sum all-reduce the policy abolishes.
        # No-op without an installed cascade policy.
        q = constrain_matmul_input(q)
        k = constrain_matmul_input(k)
        v = constrain_matmul_input(v)
        pos = pos_rows(cache["pos"], b)                 # (B,) next write index
        bt = cache.get("block_table")                   # (B, nb) => paged pool
        ps_page = cache["k"].shape[1] if bt is not None else 0
        t = bt.shape[-1] * ps_page if bt is not None else cache["k"].shape[1]
        nv = jnp.asarray(s if n_valid is None else n_valid, jnp.int32)
        if bt is not None:
            # paged pool: scatter the new K/V through the block table, then
            # gather the slot's pages back into the SAME dense (B, T, ...)
            # view the dense branch attends over. Rows backed by the trash
            # page are garbage but sit above pos — the -1e30 mask zeroes
            # them exactly, so this path is bit-identical to the dense one.
            assert cfg.window == 0, "paged attention requires full attention"
            ck = cache_utils.paged_update_rows(cache["k"], k, bt, pos, ps_page)
            cv = cache_utils.paged_update_rows(cache["v"], v, bt, pos, ps_page)
            rows = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            valid = jnp.arange(t)[None, None, :] <= rows[:, :, None]
            new_cache = {"k": ck, "v": cv, "pos": pos + nv}
            att_k = cache_utils.paged_gather(ck, bt, ps_page)
            att_v = cache_utils.paged_gather(cv, bt, ps_page)
        elif cfg.window > 0 and mode == "decode":       # ring buffer, one token
            idx = pos % t
            ck = update_rows(cache["k"], k, idx)
            cv = update_rows(cache["v"], v, idx)
            slot_pos = pos_slots(cache["slot_pos"], b, t)
            slot_pos = jax.vmap(
                lambda sp, p, i: jax.lax.dynamic_update_slice(sp, p[None], (i,)))(
                    slot_pos, pos, idx)                 # (B, T)
            valid = ((slot_pos >= 0) & (slot_pos <= pos[:, None])
                     & (pos[:, None] - slot_pos < cfg.window))[:, None, :]  # (B, 1, T)
            new_cache = {"k": ck, "v": cv, "pos": pos + 1, "slot_pos": slot_pos}
            att_k, att_v = ck, cv
        elif cfg.window > 0:  # ring-buffer chunked extend
            # A chunk may overwrite ring entries that earlier chunk tokens
            # still attend to, so attention runs over [ring, chunk] FIRST
            # and only then are the valid chunk tokens scattered in (write
            # index t = out of bounds = dropped). s <= t keeps within-chunk
            # ring writes collision-free.
            assert s <= t, "prefill chunk must fit inside the ring buffer"
            slot_pos = pos_slots(cache["slot_pos"], b, t)
            rows = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]   # (B, s)
            key_pos = jnp.concatenate([slot_pos, rows], axis=1)             # (B, T+s)
            att_k = jnp.concatenate([cache["k"].astype(jnp.float32),
                                     k.astype(jnp.float32)], axis=1)
            att_v = jnp.concatenate([cache["v"].astype(jnp.float32),
                                     v.astype(jnp.float32)], axis=1)
            valid = ((key_pos[:, None, :] >= 0)
                     & (key_pos[:, None, :] <= rows[:, :, None])
                     & (rows[:, :, None] - key_pos[:, None, :] < cfg.window))
            widx = jnp.where(jnp.arange(s, dtype=jnp.int32)[None, :] < nv,
                             rows % t, t)               # (B, s); t => dropped
            scat = lambda bu, ne, ix: bu.at[ix].set(ne.astype(bu.dtype), mode="drop")
            ck = jax.vmap(scat)(cache["k"], k, widx)
            cv = jax.vmap(scat)(cache["v"], v, widx)
            new_slot = jax.vmap(scat)(slot_pos, rows, widx)
            new_cache = {"k": ck, "v": cv, "pos": pos + nv, "slot_pos": new_slot}
        else:
            ck = update_rows(cache["k"], k, pos)
            cv = update_rows(cache["v"], v, pos)
            rows = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]   # (B, s)
            valid = jnp.arange(t)[None, None, :] <= rows[:, :, None]        # (B, s, T)
            new_cache = {"k": ck, "v": cv, "pos": pos + nv}
            att_k, att_v = ck, cv
        if ccfg.use_kernel and mode == "decode" and s == 1:
            # fused serving path: the Pallas decode-attention kernel over the
            # stacked cache (mask-as-validity covers full caches and ring
            # buffers alike). Interpret mode runs the exact single-block
            # kernel, bit-identical to the jnp math below; compiled TPU runs
            # the streaming-softmax kernel. extend/verify chunks (s > 1)
            # keep the jnp path — the fused step is the decode hot loop.
            from repro.kernels import ops  # lazy: keeps dryrun import-light
            o = ops.decode_attention(q[:, 0], att_k, att_v, valid[:, 0],
                                     scale=scale).reshape(b, s, h, hd)
        else:
            qd = q.astype(jnp.float32).reshape(b, s, hk, h // hk, hd)
            logits = jnp.einsum("bshgd,bthd->bhgst", qd, att_k.astype(jnp.float32)) * scale
            # valid: (B, s, T[+s]) (or (B, 1, T) ring) -> broadcast vs (b,hk,g,s,t)
            logits = jnp.where(valid[:, None, None], logits, -1e30)
            p = jax.nn.softmax(logits, axis=-1)
            o = jnp.einsum("bhgst,bthd->bshgd", p, att_v.astype(jnp.float32)).reshape(b, s, h, hd)
    else:
        if cfg.q_chunk > 0 and s > cfg.q_chunk:
            o = _chunked_causal_sdpa(q, k, v, scale, cfg.q_chunk, cfg.window)
        else:
            rows = jnp.arange(s)
            m = rows[:, None] >= rows[None, :]
            if cfg.window > 0:
                m &= (rows[:, None] - rows[None, :]) < cfg.window
            o = _sdpa(q, k, v, m, scale)
        new_cache = None
        if mode == "prefill":
            new_cache = _build_cache_from_prefill(k, v, cfg, s, max_len=max_len,
                                                  dtype=ccfg.resolved_kv_dtype)

    out = cascade.linear_apply(params["wo"], o.astype(x.dtype).reshape(b, s, h * hd), ccfg)
    return out, new_cache


def _build_cache_from_prefill(k: jax.Array, v: jax.Array, cfg: AttnConfig, s: int,
                              max_len: int | None = None,
                              dtype=None) -> dict:
    """Construct a decode-ready cache from prefill K/V (positions 0..s-1).

    Positions are per-row (all rows start at s); ``dtype`` overrides the KV
    storage dtype (CascadeConfig.kv_dtype plumbing — fp8 halves decode HBM).
    """
    b, _, hk, hd = k.shape
    if dtype is not None:
        k, v = k.astype(dtype), v.astype(dtype)
    pos = jnp.full((b,), s, jnp.int32)
    if cfg.window > 0:
        t = cfg.window
        if s >= t:
            k_last, v_last = k[:, s - t:], v[:, s - t:]
            pos_last = jnp.arange(s - t, s, dtype=jnp.int32)
        else:
            pad = [(0, 0), (0, t - s), (0, 0), (0, 0)]
            k_last, v_last = jnp.pad(k, pad), jnp.pad(v, pad)
            pos_last = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                                        jnp.full((t - s,), -1, jnp.int32)])
        shift = s % t if s >= t else 0
        return {
            "k": jnp.roll(k_last, shift, axis=1),
            "v": jnp.roll(v_last, shift, axis=1),
            "slot_pos": jnp.broadcast_to(jnp.roll(pos_last, shift), (b, t)),
            "pos": pos,
        }
    t = max_len if max_len is not None else s
    pad = [(0, 0), (0, t - s), (0, 0), (0, 0)]
    return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad), "pos": pos}


def attn_cache_init(batch: int, max_len: int, cfg: AttnConfig, dtype=jnp.bfloat16) -> dict:
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.window > 0:
        # the ring must hold the FULL attention window regardless of
        # max_len: windowed state is O(window), and a shorter ring would
        # silently truncate attention for prompts beyond max_len (matching
        # _build_cache_from_prefill, which also allocates t = window)
        t = cfg.window
        return {
            "k": jnp.zeros((batch, t, hk, hd), dtype),
            "v": jnp.zeros((batch, t, hk, hd), dtype),
            "slot_pos": jnp.full((batch, t), -1, jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, hk, hd), dtype),
        "v": jnp.zeros((batch, max_len, hk, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def attn_cache_init_paged(batch: int, num_pages: int, page_size: int,
                          cfg: AttnConfig, dtype=jnp.bfloat16) -> dict:
    """Paged-pool attention cache: K/V live in a shared page pool instead of
    per-slot dense rows. The (B, nb) block table is NOT a cache leaf — the
    host owns it and threads it in per step via the batch dict (page
    allocation is a host decision; the device cache stays donate-safe)."""
    assert cfg.window == 0, "paged attention requires full attention"
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((num_pages, page_size, hk, hd), dtype),
        "v": jnp.zeros((num_pages, page_size, hk, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, d: int, d_ff: int, kind: str, ccfg: CascadeConfig) -> dict:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": cascade.linear_init(ks[0], d, d_ff, ccfg),
            "w_up": cascade.linear_init(ks[1], d, d_ff, ccfg),
            "w_down": cascade.linear_init(ks[2], d_ff, d, ccfg),
        }
    # relu2 (nemotron squared-ReLU) / gelu (musicgen)
    return {
        "w_up": cascade.linear_init(ks[0], d, d_ff, ccfg),
        "w_down": cascade.linear_init(ks[1], d_ff, d, ccfg),
    }


def mlp_apply(params: dict, x: jax.Array, kind: str, ccfg: CascadeConfig) -> jax.Array:
    if kind == "swiglu":
        g = cascade.linear_apply(params["w_gate"], x, ccfg)
        u = cascade.linear_apply(params["w_up"], x, ccfg)
        h = jax.nn.silu(g) * u
    elif kind == "geglu":
        g = cascade.linear_apply(params["w_gate"], x, ccfg)
        u = cascade.linear_apply(params["w_up"], x, ccfg)
        h = jax.nn.gelu(g) * u
    elif kind == "relu2":
        u = cascade.linear_apply(params["w_up"], x, ccfg)
        h = jnp.square(jax.nn.relu(u))
    elif kind == "gelu":
        u = cascade.linear_apply(params["w_up"], x, ccfg)
        h = jax.nn.gelu(u)
    else:
        raise ValueError(kind)
    return cascade.linear_apply(params["w_down"], h, ccfg)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed_apply(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def sinusoidal_positions(s: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None] + offset
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(jnp.bfloat16)

"""Mixture-of-Experts LMs: DeepSeek-V2 (MLA + shared/routed experts, top-6)
and OLMoE (GQA + 64 routed experts, top-8).

Routing uses capacity-based scatter dispatch (no (T, E, C) one-hot tensor —
the dispatch buffer is built with a scatter-add and read back with a gather,
so memory is O(T*E) ints + O(E*C*d) activations; both shard cleanly: tokens
on the ``data`` axis, experts on the ``model`` axis = expert parallelism).

MLA (multi-head latent attention, arXiv:2405.04434): KV compressed to a
512-dim latent + 64-dim decoupled RoPE key. Decode uses the weight-absorption
identity (scores = (q W_k)·c_kv) so the cache stays in latent space —
(kv_lora + rope) bytes/token instead of 2*H*head_dim.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cascade
from repro.core.cascade import CascadeConfig
from repro.configs.base import ArchConfig
from repro.distributed.sharding import (constrain_expert_buffer,
                                        constrain_matmul_input,
                                        constrain_replicated,
                                        constrain_residual)
from repro.models import layers as L
from repro.models.cache_utils import (StackedCacheMixin, paged_gather,
                                      paged_rows_restore, paged_rows_snapshot,
                                      paged_update_rows, seq_rows_restore,
                                      seq_rows_snapshot, take_last_valid)


def _remat_policy(name: str):
    import jax as _jax
    return {
        "dots": _jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "none": _jax.checkpoint_policies.nothing_saveable,
        "save_all": _jax.checkpoint_policies.everything_saveable,
    }[name]


# ---------------------------------------------------------------------------
# routed-expert FFN
# ---------------------------------------------------------------------------

def moe_ffn_init(key: jax.Array, cfg: ArchConfig, ccfg: CascadeConfig) -> dict:
    ks = jax.random.split(key, 5)
    d, e, dff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02,
        "wg": cascade.expert_linear_init(ks[1], e, d, dff, ccfg),
        "wu": cascade.expert_linear_init(ks[2], e, d, dff, ccfg),
        "wd": cascade.expert_linear_init(ks[3], e, dff, d, ccfg),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, cfg.n_shared_experts * dff, "swiglu", ccfg)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = math.ceil(cfg.moe_capacity_factor * n_tokens * cfg.moe_top_k / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_ffn_apply(params: dict, x: jax.Array, cfg: ArchConfig, ccfg: CascadeConfig,
                  no_drop: bool = False) -> jax.Array:
    """Capacity-dispatched routed experts.

    ``no_drop=True`` (serving paths) sizes the buffer for worst-case
    routing skew so NO token is ever capacity-dropped: per-token outputs
    then depend only on that token, never on batch composition — which is
    what makes batched/chunked decode token-exact against the slot-wise
    reference. top_k experts are DISTINCT per token, so one expert can
    receive at most t assignments — capacity t suffices. Training keeps
    the ``moe_capacity_factor`` drop semantics.
    """
    b, s, d = x.shape
    t = b * s
    k, e = cfg.moe_top_k, cfg.n_experts
    cap = (-(-t // 8) * 8) if no_drop else _capacity(t, cfg)
    xf = x.reshape(t, d)
    if no_drop:
        # serving (decode/extend) token counts are tiny: replicate them over
        # the mesh before the dispatch scatter so the buffer is built locally
        # on every shard — no cross-shard scatter-add, hence no partial-sum
        # all-reduce in the cascade decode step (no-op without a mesh policy;
        # train keeps data-sharded tokens and the documented dispatch reduce)
        xf = constrain_replicated(xf)

    logits = jnp.dot(xf.astype(jnp.float32), params["router"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, k)                                  # (T, k)
    if cfg.moe_renorm:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)                                          # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_e = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                                   flat_e[:, None], axis=1)[:, 0]     # (T*k,)
    keep = pos_in_e < cap
    dst = jnp.where(keep, flat_e * cap + pos_in_e, e * cap)           # OOB = dropped

    xk = jnp.repeat(xf, k, axis=0)                                    # (T*k, d) token-major
    if no_drop and xk.shape[0] * e * cap <= (1 << 22):
        # one-hot dot dispatch for serving-sized token counts: in no_drop
        # mode every assignment owns a UNIQUE buffer row (capacity = t,
        # distinct experts per token), so this is bitwise the scatter below
        # (each output row sums one real value and exact zeros) — but GSPMD
        # provably keeps a dot over replicated operands local, whereas it
        # may partition the scatter-add over data shards and recombine with
        # exactly the partial-sum all-reduce the cascade decode step must
        # not contain. Big chunked-prefill dispatches (where the one-hot
        # would not fit) keep the scatter.
        xk = constrain_replicated(xk)
        sel = jax.nn.one_hot(dst, e * cap, dtype=xk.dtype)            # (T*k, E*C)
        buf = constrain_replicated(jnp.einsum("te,td->ed", sel, xk))
    else:
        buf = jnp.zeros((e * cap, d), xf.dtype).at[dst].add(xk, mode="drop")
    buf = constrain_expert_buffer(buf.reshape(e, cap, d))

    h = jax.nn.silu(cascade.expert_linear_apply(params["wg"], buf, ccfg).astype(jnp.float32))
    h = (h * cascade.expert_linear_apply(params["wu"], buf, ccfg).astype(jnp.float32)).astype(buf.dtype)
    out = constrain_expert_buffer(
        cascade.expert_linear_apply(params["wd"], h, ccfg))           # (E, C, d)

    outf = out.reshape(e * cap, d)
    got = jnp.take(outf, jnp.minimum(dst, e * cap - 1), axis=0)
    got = jnp.where(keep[:, None], got, 0.0)
    y = jnp.sum((got.astype(jnp.float32)
                 * gates.reshape(-1)[:, None]).reshape(t, k, d), axis=1)

    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xf, "swiglu", ccfg).astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(key: jax.Array, cfg: ArchConfig, ccfg: CascadeConfig) -> dict:
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {
        "wq_a": cascade.linear_init(ks[0], d, cfg.q_lora, ccfg),
        "q_norm": L.norm_init(cfg.q_lora),
        "wq_b": cascade.linear_init(ks[1], cfg.q_lora, h * qk, ccfg),
        "wkv_a": cascade.linear_init(ks[2], d, cfg.kv_lora + cfg.qk_rope_dim, ccfg),
        "kv_norm": L.norm_init(cfg.kv_lora),
        "wkv_b": cascade.linear_init(ks[3], cfg.kv_lora, h * (cfg.qk_nope_dim + cfg.v_head_dim), ccfg),
        "wo": cascade.linear_init(ks[4], h * cfg.v_head_dim, d, ccfg),
    }
    return p


def _mla_qkr(params, x, cfg, ccfg, positions):
    """Shared q / latent-kv projection + rope. Returns q_nope, q_rope, c_kv, k_rope."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q = cascade.linear_apply(params["wq_b"],
                             L.norm_apply(params["q_norm"],
                                          cascade.linear_apply(params["wq_a"], x, ccfg)),
                             ccfg).reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    kv = cascade.linear_apply(params["wkv_a"], x, ccfg)
    c_kv = L.norm_apply(params["kv_norm"], kv[..., : cfg.kv_lora])
    k_rope = kv[..., cfg.kv_lora:][:, :, None, :]                     # (b,s,1,rope)
    # CASCADE pin (see layers.attn_apply): these are column-sharded
    # projection slices; carried sharded into rope, the concatenate of the
    # rotated halves lowers to a masked cross-shard add, and the score
    # contractions would split. Batch stays over data, features replicate.
    # No-op without an installed cascade policy.
    q_nope = constrain_matmul_input(q_nope)
    q_rope = constrain_matmul_input(q_rope)
    c_kv = constrain_matmul_input(c_kv)
    k_rope = constrain_matmul_input(k_rope)
    inv = L.rope_freqs(cfg.qk_rope_dim, cfg.rope_theta, 1.0)
    q_rope = L.apply_rope(q_rope, positions, inv)
    k_rope = L.apply_rope(k_rope, positions, inv)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(params, x, cfg: ArchConfig, ccfg, cache=None, mode="full", max_len=None,
              n_valid=None):
    b, s, _ = x.shape
    h = cfg.n_heads
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    if cache is not None:
        positions = (L.pos_rows(cache["pos"], b)[:, None]
                     + jnp.arange(s, dtype=jnp.int32)[None, :])
    else:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, cfg, ccfg, positions)

    wkv_b = cascade.linear_weight(params["wkv_b"], ccfg)              # (kv_lora, H*(nope+v))
    wkv_b = wkv_b.reshape(cfg.kv_lora, h, cfg.qk_nope_dim + cfg.v_head_dim)
    w_k = wkv_b[..., : cfg.qk_nope_dim]                               # (lora, H, nope)
    w_v = wkv_b[..., cfg.qk_nope_dim:]                                # (lora, H, v)

    if mode in ("decode", "extend"):
        # decode: one new token; extend: a (right-padded) chunk of s tokens
        # at each row's position — pad latents land mask-invalid above the
        # valid region and are overwritten by the next write.
        assert mode == "extend" or s == 1
        pos = L.pos_rows(cache["pos"], b)                     # (B,) per-slot
        nv = jnp.asarray(s if n_valid is None else n_valid, jnp.int32)
        bt = cache.get("block_table")
        if bt is not None:
            # paged latent pool: scatter through the block table, gather the
            # slot's pages back into the dense (B, T, ...) view the scores
            # below contract over — bit-identical to the dense path (trash-
            # page rows sit above pos where the -1e30 mask zeroes them).
            ps_page = cache["c_kv"].shape[1]
            ckv_pool = paged_update_rows(cache["c_kv"], c_kv, bt, pos, ps_page)
            krp_pool = paged_update_rows(cache["k_rope"], k_rope, bt, pos, ps_page)
            ckv = paged_gather(ckv_pool, bt, ps_page)
            krp = paged_gather(krp_pool, bt, ps_page)
            t = bt.shape[-1] * ps_page
            new_cache = {"c_kv": ckv_pool, "k_rope": krp_pool, "pos": pos + nv}
        else:
            ckv = L.update_rows(cache["c_kv"], c_kv, pos)
            krp = L.update_rows(cache["k_rope"], k_rope, pos)
            t = ckv.shape[1]
            new_cache = {"c_kv": ckv, "k_rope": krp, "pos": pos + nv}
        rows = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # (B, s)
        # weight absorption: stay in latent space
        q_lat = jnp.einsum("bshd,lhd->bshl", q_nope.astype(jnp.float32), w_k.astype(jnp.float32))
        scores = (jnp.einsum("bshl,btl->bhst", q_lat, ckv.astype(jnp.float32))
                  + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32), krp.astype(jnp.float32))) * scale
        valid = jnp.arange(t)[None, None, :] <= rows[:, :, None]       # (B, s, T)
        scores = jnp.where(valid[:, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btl->bshl", p, ckv.astype(jnp.float32))
        o = jnp.einsum("bshl,lhd->bshd", ctx, w_v.astype(jnp.float32))  # (b,s,H,v)
    else:
        # expand latents to per-head keys/values (prefill & train)
        k_nope = jnp.einsum("btl,lhd->bthd", c_kv.astype(jnp.float32), w_k.astype(jnp.float32))
        v = jnp.einsum("btl,lhd->bthd", c_kv.astype(jnp.float32), w_v.astype(jnp.float32))
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, cfg.qk_rope_dim))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        cd = ccfg.compute_dtype
        if cfg.q_chunk > 0 and s > cfg.q_chunk:
            o = L._chunked_causal_sdpa(q_full.astype(cd), k_full.astype(cd),
                                       v.astype(cd), scale, cfg.q_chunk, 0)
        else:
            rows = jnp.arange(s)
            m = rows[:, None] >= rows[None, :]
            o = L._sdpa(q_full.astype(cd), k_full.astype(cd), v.astype(cd), m, scale)
        o = o[..., : cfg.v_head_dim]
        new_cache = None
        if mode == "prefill":
            t = max_len if max_len is not None else s
            pad = [(0, 0), (0, t - s), (0, 0)]
            kvd = ccfg.resolved_kv_dtype
            new_cache = {"c_kv": jnp.pad(c_kv.astype(kvd), pad),
                         "k_rope": jnp.pad(k_rope.astype(kvd), pad),
                         "pos": jnp.full((b,), s, jnp.int32)}

    out = cascade.linear_apply(params["wo"], o.astype(x.dtype).reshape(b, s, h * cfg.v_head_dim), ccfg)
    return out, new_cache


def mla_cache_init(batch: int, max_len: int, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def mla_cache_init_paged(batch: int, num_pages: int, page_size: int,
                         cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((num_pages, page_size, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((num_pages, page_size, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MoE LM (DeepSeek-V2 / OLMoE)
# ---------------------------------------------------------------------------

class MoELM(StackedCacheMixin):
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.use_mla = cfg.kv_lora > 0
        if not self.use_mla:
            self.attn_cfg = L.AttnConfig(
                d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias,
                rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk)

    # ------------------------------------------------------------------ init
    def _attn_init(self, key, ccfg):
        return (mla_init(key, self.cfg, ccfg) if self.use_mla
                else L.attn_init(key, self.attn_cfg, ccfg))

    def _moe_layer_init(self, key, ccfg):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_init(cfg.d_model, cfg.norm_type),
            "attn": self._attn_init(k1, ccfg),
            "ln2": L.norm_init(cfg.d_model, cfg.norm_type),
            "moe": moe_ffn_init(k2, cfg, ccfg),
        }

    def _dense_layer_init(self, key, ccfg):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_init(cfg.d_model, cfg.norm_type),
            "attn": self._attn_init(k1, ccfg),
            "ln2": L.norm_init(cfg.d_model, cfg.norm_type),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.dense_d_ff or cfg.d_ff, "swiglu", ccfg),
        }

    def init_params(self, key, ccfg):
        cfg = self.cfg
        n_moe = cfg.n_layers - cfg.first_dense_layers
        keys = jax.random.split(key, n_moe + cfg.first_dense_layers + 2)
        params = {
            "dense_layers": [self._dense_layer_init(keys[i], ccfg)
                             for i in range(cfg.first_dense_layers)],
            "layers": jax.vmap(lambda k: self._moe_layer_init(k, ccfg))(
                keys[cfg.first_dense_layers: cfg.first_dense_layers + n_moe]),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm_type),
            "embed": L.embed_init(keys[-2], cfg.vocab, cfg.d_model, dtype=ccfg.compute_dtype),
            "lm_head": cascade.linear_init(keys[-1], cfg.d_model, cfg.vocab, ccfg),
        }
        return params

    # --------------------------------------------------------------- blocks
    def _attn_apply(self, lp, x, ccfg, cache, mode, max_len=None, n_valid=None):
        if self.use_mla:
            return mla_apply(lp, x, self.cfg, ccfg, cache, mode, max_len, n_valid=n_valid)
        return L.attn_apply(lp, x, self.attn_cfg, ccfg, cache=cache, mode=mode,
                            max_len=max_len, n_valid=n_valid)

    def _block(self, lp, x, ccfg, cache, mode, moe: bool, max_len=None, n_valid=None):
        cfg = self.cfg
        h, nc = self._attn_apply(lp["attn"], L.norm_apply(lp["ln1"], x, cfg.norm_type),
                                 ccfg, cache, mode, max_len, n_valid)
        x = x + h
        u = L.norm_apply(lp["ln2"], x, cfg.norm_type)
        if moe:
            # serving modes dispatch drop-free: capacity drops would make a
            # token's output depend on unrelated slots / chunk boundaries,
            # breaking batched-vs-slotwise parity (train keeps drops)
            x = x + self._moe_ffn(lp["moe"], u, ccfg, no_drop=(mode != "full"))
        else:
            x = x + L.mlp_apply(lp["mlp"], u, "swiglu", ccfg)
        return constrain_residual(x), nc

    def _moe_ffn(self, lp_moe, u, ccfg, no_drop=False):
        """Dispatch strategy: shard_map expert parallelism when the launcher
        installed a policy with moe_ep=True (kills the GSPMD scatter
        all-reduce, see models/moe_shardmap.py); jit capacity-dispatch
        otherwise (CPU tests / no mesh). The EP path keeps capacity
        semantics (it is a training/lowering surface, not the engine's)."""
        from repro.distributed.sharding import get_activation_policy
        pol = get_activation_policy()
        if pol and pol.get("moe_ep") and pol.get("mesh") is not None:
            from repro.models.moe_shardmap import moe_ffn_apply_ep
            return moe_ffn_apply_ep(lp_moe, u, self.cfg, ccfg, pol["mesh"],
                                    batch_axes=pol["batch_axes"])
        return moe_ffn_apply(lp_moe, u, self.cfg, ccfg, no_drop=no_drop)

    # --------------------------------------------------------------- api
    def _head(self, params, x, ccfg):
        x = L.norm_apply(params["final_norm"], x, self.cfg.norm_type)
        return cascade.linear_apply(params["lm_head"], x, ccfg).astype(jnp.float32)

    def forward(self, params, batch, ccfg, remat: bool = False,
                remat_policy: str = "dots"):
        x = L.embed_apply(params["embed"], batch["tokens"])
        for dp in params["dense_layers"]:
            x, _ = self._block(dp, x, ccfg, None, "full", moe=False)

        def body(x, lp):
            y, _ = self._block(lp, x, ccfg, None, "full", moe=True)
            return y, None

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(remat_policy))
        x, _ = lax.scan(body, x, params["layers"])
        return self._head(params, x, ccfg)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg

        def one(_):
            return (mla_cache_init(batch, max_len, cfg, dtype) if self.use_mla
                    else L.attn_cache_init(batch, max_len, self.attn_cfg, dtype))

        n_moe = cfg.n_layers - cfg.first_dense_layers
        return {
            "dense_layers": [one(None) for _ in range(cfg.first_dense_layers)],
            "layers": jax.vmap(one)(jnp.arange(n_moe)),
        }

    # ------------------------------------------------------- paged cache API
    @property
    def paged_attention(self) -> bool:
        return True  # MLA latents and GQA KV both page (full attention)

    def init_paged_cache(self, batch: int, num_pages: int, page_size: int,
                         dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg

        def one(_):
            return (mla_cache_init_paged(batch, num_pages, page_size, cfg, dtype)
                    if self.use_mla
                    else L.attn_cache_init_paged(batch, num_pages, page_size,
                                                 self.attn_cfg, dtype))

        n_moe = cfg.n_layers - cfg.first_dense_layers
        return {
            "dense_layers": [one(None) for _ in range(cfg.first_dense_layers)],
            "layers": jax.vmap(one)(jnp.arange(n_moe)),
        }

    def paged_copy_page(self, cache: dict, src, dst) -> dict:
        """Copy physical page ``src`` to ``dst`` in every pool leaf (radix
        copy-on-write). Per-layer dense caches carry the page axis first;
        the scanned stack carries it after the layer axis."""
        cp_flat = lambda c: {k: (v if k == "pos" else v.at[dst].set(v[src]))
                             for k, v in c.items()}
        cp_stk = lambda c: {k: (v if k == "pos" else v.at[:, dst].set(v[:, src]))
                            for k, v in c.items()}
        return {"dense_layers": [cp_flat(c) for c in cache["dense_layers"]],
                "layers": cp_stk(cache["layers"])}

    def prefill(self, params, batch, ccfg, max_len: int | None = None):
        x = L.embed_apply(params["embed"], batch["tokens"])
        dense_caches = []
        for dp in params["dense_layers"]:
            x, c = self._block(dp, x, ccfg, None, "prefill", moe=False, max_len=max_len)
            dense_caches.append(c)

        def body(x, lp):
            y, c = self._block(lp, x, ccfg, None, "prefill", moe=True, max_len=max_len)
            return y, c

        x, caches = lax.scan(body, x, params["layers"])
        logits = self._head(params, x[:, -1:], ccfg)
        return logits, {"dense_layers": dense_caches, "layers": caches}

    def decode_step(self, params, batch, cache, ccfg):
        x = L.embed_apply(params["embed"], batch["tokens"])
        bt = batch.get("block_table")
        new_dense = []
        for dp, dc in zip(params["dense_layers"], cache["dense_layers"]):
            if bt is not None:
                dc = dict(dc, block_table=bt)
            x, nc = self._block(dp, x, ccfg, dc, "decode", moe=False)
            new_dense.append(nc)

        def body(x, scanned):
            lp, c = scanned
            if bt is not None:
                c = dict(c, block_table=bt)
            y, nc = self._block(lp, x, ccfg, c, "decode", moe=True)
            return y, nc

        x, new_caches = lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = self._head(params, x, ccfg)
        return logits, {"dense_layers": new_dense, "layers": new_caches}

    def prefill_extend(self, params, batch, cache, ccfg, n_valid=None,
                       all_logits: bool = False):
        """Append a (right-padded) token chunk to an existing MLA latent (or
        GQA) cache — the continuous-batching admission path. Pad positions
        never influence valid tokens (mask-invalid and overwritten by the
        next write); routed experts see pad tokens but their outputs are
        sliced away. Returns logits for the last valid token, (B, 1, V) —
        or for every chunk position, (B, S, V), when ``all_logits`` is set
        (the speculative-decode verify pass)."""
        x = L.embed_apply(params["embed"], batch["tokens"])
        b, s = batch["tokens"].shape
        nv = jnp.asarray(s if n_valid is None else n_valid, jnp.int32)
        bt = batch.get("block_table")
        new_dense = []
        for dp, dc in zip(params["dense_layers"], cache["dense_layers"]):
            if bt is not None:
                dc = dict(dc, block_table=bt)
            x, nc = self._block(dp, x, ccfg, dc, "extend", moe=False, n_valid=nv)
            new_dense.append(nc)

        def body(x, scanned):
            lp, c = scanned
            if bt is not None:
                c = dict(c, block_table=bt)
            y, nc = self._block(lp, x, ccfg, c, "extend", moe=True, n_valid=nv)
            return y, nc

        x, new_caches = lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = self._head(params, x if all_logits else take_last_valid(x, nv), ccfg)
        return logits, {"dense_layers": new_dense, "layers": new_caches}

    # --------------------------------------------------- speculative decode
    def spec_verify(self, params, batch, cache, ccfg):
        """Score a (B, 1+K) draft chunk in ONE extend pass (drop-free expert
        dispatch keeps per-token routing independent of the draft batch):
        per-position logits, advanced cache, and the overwritten MLA-latent
        (or GQA KV) rows as the rewind checkpoint."""
        s = batch["tokens"].shape[1]
        bt = batch.get("block_table")
        if bt is not None:
            ckpt = {"dense_layers": [paged_rows_snapshot(c, bt, s)
                                     for c in cache["dense_layers"]],
                    "layers": paged_rows_snapshot(cache["layers"], bt, s),
                    "block_table": bt}
        else:
            ckpt = {"dense_layers": [seq_rows_snapshot(c, s)
                                     for c in cache["dense_layers"]],
                    "layers": seq_rows_snapshot(cache["layers"], s)}
        logits, cache = self.prefill_extend(params, batch, cache, ccfg,
                                            all_logits=True)
        return logits, cache, ckpt

    def spec_rewind(self, cache, ckpt, keep):
        """Per-slot rewind: restore rejected latent/KV rows, rewind pos."""
        bt = ckpt.get("block_table")
        if bt is not None:
            return {"dense_layers": [paged_rows_restore(c, k, bt, keep) for c, k in
                                     zip(cache["dense_layers"], ckpt["dense_layers"])],
                    "layers": paged_rows_restore(cache["layers"], ckpt["layers"], bt, keep)}
        return {"dense_layers": [seq_rows_restore(c, k, keep) for c, k in
                                 zip(cache["dense_layers"], ckpt["dense_layers"])],
                "layers": seq_rows_restore(cache["layers"], ckpt["layers"], keep)}

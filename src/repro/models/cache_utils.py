"""Generic continuous-batching slot operations over arbitrary cache pytrees.

The serving engine keeps ONE fixed-shape stacked cache for the whole slot
grid and admits/retires requests as slot writes (``serve/engine.py``). The
slot axis is not uniform across leaves: layer-stacked buffers are
``(L, B, ...)`` (slot axis 1), while per-layer Python-list caches (MoE dense
layers, Griffin tail blocks) and grid-level position tables are ``(B, ...)``
(slot axis 0). Rather than hand-annotating every model's cache schema, the
axis of each leaf is discovered once by **probing**: build the cache at two
batch sizes and record, per leaf, the single axis whose extent changed.

Every cache leaf must therefore carry a batch/slot dimension — scalar
bookkeeping (e.g. a shared position counter) has to be stored per-slot,
which is what continuous batching needs anyway.
"""
from __future__ import annotations

from typing import Any, Callable, List

import jax
import jax.numpy as jnp
from jax import lax

#: probe shapes — small enough to be free, distinct enough to be unambiguous
_PROBE_BATCHES = (2, 3)
_PROBE_LEN = 8


def probe_slot_axes(init_cache: Callable[..., Any], probe_len: int = _PROBE_LEN) -> Any:
    """Pytree of per-leaf slot-axis indices, discovered by shape probing.

    ``init_cache(batch, max_len)`` is called at two batch sizes; for each
    leaf exactly one axis must differ — that axis is the slot axis.
    """
    b0, b1 = _PROBE_BATCHES
    small, big = init_cache(b0, probe_len), init_cache(b1, probe_len)

    def axis_of(a, b):
        diffs = [i for i, (p, q) in enumerate(zip(a.shape, b.shape)) if p != q]
        if len(diffs) != 1:
            raise ValueError(
                f"cache leaf {a.shape} -> {b.shape}: expected exactly one "
                f"batch-dependent axis, found {diffs} — every cache leaf "
                "must carry a per-slot dimension")
        return diffs[0]

    return jax.tree.map(axis_of, small, big)


def stack_caches(spec: Any, caches: List[Any]) -> Any:
    """Concatenate per-request caches along each leaf's slot axis."""
    return jax.tree.map(lambda ax, *xs: jnp.concatenate(xs, axis=ax), spec, *caches)


def cache_at(spec: Any, cache: Any, i) -> Any:
    """Batch-1 view of slot ``i`` (failover handoff / inspection)."""
    return jax.tree.map(
        lambda ax, x: lax.dynamic_slice_in_dim(x, i, 1, axis=ax), spec, cache)


def write_cache(spec: Any, cache: Any, sub: Any, i) -> Any:
    """Write a batch-1 cache ``sub`` into slot ``i`` of a stacked cache."""
    return jax.tree.map(
        lambda ax, c, s: lax.dynamic_update_slice_in_dim(
            c, s.astype(c.dtype), i, axis=ax),
        spec, cache, sub)


def take_last_valid(x: jax.Array, n_valid) -> jax.Array:
    """(B, S, ...) -> (B, 1, ...) slice at index ``n_valid - 1`` per row.

    The chunked-prefill epilogue: chunks are right-padded, so the logits
    row that continues the stream is the last VALID one, not row S-1.
    ``n_valid`` may be a traced scalar.
    """
    b = x.shape[0]
    nv = jnp.asarray(x.shape[1] if n_valid is None else n_valid, jnp.int32)
    last = jnp.broadcast_to(nv, (b,)) - 1
    return jax.vmap(lambda xi, j: lax.dynamic_slice_in_dim(xi, j, 1, axis=0))(x, last)


class StackedCacheMixin:
    """Stacked-cache protocol shared by every registry model.

    Provides ``stack_caches`` / ``cache_at`` / ``write_cache`` on top of the
    model's own ``init_cache``; the per-leaf slot axes are probed lazily on
    first use and memoized (pure Python ints — safe to reuse across jit
    traces, including under donation).
    """

    _slot_axes: Any = None

    def _slot_spec(self):
        if self._slot_axes is None:
            self._slot_axes = probe_slot_axes(self.init_cache)
        return self._slot_axes

    def stack_caches(self, caches: list):
        return stack_caches(self._slot_spec(), caches)

    def cache_at(self, cache, i):
        return cache_at(self._slot_spec(), cache, i)

    def write_cache(self, cache, sub, i):
        return write_cache(self._slot_spec(), cache, sub, i)

"""Generic continuous-batching slot operations over arbitrary cache pytrees.

The serving engine keeps ONE fixed-shape stacked cache for the whole slot
grid and admits/retires requests as slot writes (``serve/engine.py``). The
slot axis is not uniform across leaves: layer-stacked buffers are
``(L, B, ...)`` (slot axis 1), while per-layer Python-list caches (MoE dense
layers, Griffin tail blocks) and grid-level position tables are ``(B, ...)``
(slot axis 0). Rather than hand-annotating every model's cache schema, the
axis of each leaf is discovered once by **probing**: build the cache at two
batch sizes and record, per leaf, the single axis whose extent changed.

Every cache leaf must therefore carry a batch/slot dimension — scalar
bookkeeping (e.g. a shared position counter) has to be stored per-slot,
which is what continuous batching needs anyway.
"""
from __future__ import annotations

from typing import Any, Callable, List

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

#: probe shapes — small enough to be free, distinct enough to be unambiguous
_PROBE_BATCHES = (2, 3)
_PROBE_LEN = 8


def probe_slot_axes(init_cache: Callable[..., Any], probe_len: int = _PROBE_LEN) -> Any:
    """Pytree of per-leaf slot-axis indices, discovered by shape probing.

    ``init_cache(batch, max_len)`` is called at two batch sizes; for each
    leaf exactly one axis must differ — that axis is the slot axis.
    """
    b0, b1 = _PROBE_BATCHES
    small, big = init_cache(b0, probe_len), init_cache(b1, probe_len)

    def axis_of(a, b):
        diffs = [i for i, (p, q) in enumerate(zip(a.shape, b.shape)) if p != q]
        if len(diffs) != 1:
            raise ValueError(
                f"cache leaf {a.shape} -> {b.shape}: expected exactly one "
                f"batch-dependent axis, found {diffs} — every cache leaf "
                "must carry a per-slot dimension")
        return diffs[0]

    return jax.tree.map(axis_of, small, big)


def slot_pspecs(spec: Any, cache: Any, mesh, data_axis: str = "data") -> Any:
    """PartitionSpec tree placing each leaf's probe-discovered SLOT axis over
    ``data_axis`` (mesh-native serving: every data shard owns a contiguous
    band of decode slots, so batched decode never moves cache state).

    Leaves whose slot extent the data axis does not divide (batch-1 staging
    caches, odd grids) fall back to replication — placement stays
    well-defined for any ``max_batch``. All non-slot dims are replicated:
    weights are the ``model``-sharded tensors in serving; slot state shards
    only by request.
    """
    size = mesh.shape.get(data_axis, 1) if mesh is not None else 1

    def one(ax, leaf):
        parts = [None] * leaf.ndim
        if size > 1 and leaf.shape[ax] % size == 0:
            parts[ax] = data_axis
        return P(*parts)

    return jax.tree.map(one, spec, cache)


def stack_caches(spec: Any, caches: List[Any]) -> Any:
    """Concatenate per-request caches along each leaf's slot axis."""
    return jax.tree.map(lambda ax, *xs: jnp.concatenate(xs, axis=ax), spec, *caches)


def cache_at(spec: Any, cache: Any, i) -> Any:
    """Batch-1 view of slot ``i`` (failover handoff / inspection)."""
    return jax.tree.map(
        lambda ax, x: lax.dynamic_slice_in_dim(x, i, 1, axis=ax), spec, cache)


def write_cache(spec: Any, cache: Any, sub: Any, i) -> Any:
    """Write a batch-1 cache ``sub`` into slot ``i`` of a stacked cache."""
    return jax.tree.map(
        lambda ax, c, s: lax.dynamic_update_slice_in_dim(
            c, s.astype(c.dtype), i, axis=ax),
        spec, cache, sub)


def take_last_valid(x: jax.Array, n_valid) -> jax.Array:
    """(B, S, ...) -> (B, 1, ...) slice at index ``n_valid - 1`` per row.

    The chunked-prefill epilogue: chunks are right-padded, so the logits
    row that continues the stream is the last VALID one, not row S-1.
    ``n_valid`` may be a traced scalar.
    """
    b = x.shape[0]
    nv = jnp.asarray(x.shape[1] if n_valid is None else n_valid, jnp.int32)
    last = jnp.broadcast_to(nv, (b,)) - 1
    return jax.vmap(lambda xi, j: lax.dynamic_slice_in_dim(xi, j, 1, axis=0))(x, last)


# ---------------------------------------------------------------------------
# speculative-decode rewind primitives
#
# A verify pass runs the fixed-shape ``prefill_extend`` path over a
# ``(B, 1+K)`` draft chunk with every token treated as real; acceptance is
# only known afterwards, so the cache writes for the rejected suffix must be
# rolled back per slot. The primitives are ACCEPTANCE-RULE AGNOSTIC: greedy
# argmax-prefix acceptance and speculative sampling (rejection resampling,
# ``serve/engine.spec_sample_accept``) both hand them the same contract —
# ``keep[b] = accepted drafts + 1`` chunk rows stay committed (the pending
# token plus the accepted prefix; the bonus/resampled token is NOT in the
# chunk — it becomes the next step's pending token), everything after rolls
# back. Two leaf families, two mechanisms:
#
# * **seq-indexed buffers** (full/windowed KV, MLA latents, ring
#   ``slot_pos``): snapshot the rows the chunk will overwrite BEFORE the
#   verify pass, then restore the rejected rows and rewind the per-slot
#   position. Dense caches only strictly need the position rewind (stale
#   rows above ``pos`` are mask-invalid), but ring buffers lose clobbered
#   in-window entries without the row restore, so both get it.
# * **recurrent state** (conv / RG-LRU h / SSD): recurrences cannot be
#   rewound in place, so the verify pass emits per-position checkpoints and
#   the rewind selects checkpoint ``keep[b]`` per slot
#   (``slice_rows_per_slot``).
# ---------------------------------------------------------------------------


def seq_rows_snapshot(cache: dict, s: int) -> dict:
    """Snapshot the ``s`` rows an extend of length ``s`` will write.

    ``cache`` is one attention-cache dict: a per-slot position table
    ``pos`` with shape ``(lead..., B)`` plus seq-indexed buffers whose row
    axis is axis ``pos.ndim`` (``k``/``v``/``c_kv``/``k_rope`` of shape
    ``(lead..., B, T, ...)``; ring ``slot_pos`` of ``(lead..., B, T)``).
    Ring caches — identified by a ``slot_pos`` leaf — write at
    ``(pos + j) % T``; linear caches at ``pos + j`` (the engine guarantees
    ``pos + s <= T`` headroom).
    """
    pos = jnp.asarray(cache["pos"], jnp.int32)
    ring = "slot_pos" in cache
    rows = pos[..., None] + jnp.arange(s, dtype=jnp.int32)   # (lead..., B, s)
    snap = {"pos": pos}
    for name, buf in cache.items():
        if name == "pos":
            continue
        t = buf.shape[pos.ndim]
        idx = rows % t if ring else jnp.minimum(rows, t - 1)
        ix = idx.reshape(idx.shape + (1,) * (buf.ndim - pos.ndim - 1))
        snap[name] = jnp.take_along_axis(buf, ix, axis=pos.ndim)
    return snap


def _scatter_rows(buf: jax.Array, idx: jax.Array, val: jax.Array, axis: int) -> jax.Array:
    """Write ``val`` rows into ``buf`` at per-lead-row indices ``idx``.

    buf: (lead..., T, rest); idx: (lead..., s); val: (lead..., s, rest).
    """
    lead = buf.shape[:axis]
    n = 1
    for d in lead:
        n *= d
    s = idx.shape[-1]
    buf2 = buf.reshape((n,) + buf.shape[axis:])
    idx2 = idx.reshape(n, s)
    val2 = val.reshape((n, s) + buf.shape[axis + 1:])
    out = jax.vmap(lambda b, i, v: b.at[i].set(v))(buf2, idx2, val2)
    return out.reshape(buf.shape)


def seq_rows_restore(cache: dict, snap: dict, keep) -> dict:
    """Rewind a seq-indexed cache after a verify pass.

    The first ``keep[b]`` chunk rows stay committed; rows ``keep[b]..s-1``
    are restored from the snapshot and the per-slot position is rewound to
    ``pos0 + keep[b]``. ``keep`` is ``(B,)`` (0 for inactive slots — a full
    rewind is the identity on the pre-verify cache).
    """
    pos0 = snap["pos"]
    keep_f = jnp.broadcast_to(jnp.asarray(keep, jnp.int32), pos0.shape)
    ring = "slot_pos" in cache
    any_buf = next(k for k in snap if k != "pos")
    s = snap[any_buf].shape[pos0.ndim]
    rows = pos0[..., None] + jnp.arange(s, dtype=jnp.int32)  # (lead..., B, s)
    rejected = jnp.arange(s, dtype=jnp.int32) >= keep_f[..., None]
    new = {"pos": pos0 + keep_f}
    for name, buf in cache.items():
        if name == "pos":
            continue
        t = buf.shape[pos0.ndim]
        idx = rows % t if ring else jnp.minimum(rows, t - 1)
        ix = idx.reshape(idx.shape + (1,) * (buf.ndim - pos0.ndim - 1))
        cur = jnp.take_along_axis(buf, ix, axis=pos0.ndim)
        mask = rejected.reshape(rejected.shape + (1,) * (buf.ndim - pos0.ndim - 1))
        new[name] = _scatter_rows(buf, idx, jnp.where(mask, snap[name], cur),
                                  axis=pos0.ndim)
    return new


def slice_rows_per_slot(ck: jax.Array, keep, b_axis: int, n: int) -> jax.Array:
    """Per-slot contiguous row slice from a checkpoint stack.

    ck: (lead..., B, C, rest...) with ``b_axis`` the B axis; returns rows
    ``keep[b] .. keep[b]+n-1`` along axis ``b_axis + 1`` — the recurrent
    rewind primitive (conv windows: n = width-1; scalar states: n = 1).
    """
    k = jnp.asarray(keep, jnp.int32)
    t_axis = b_axis + 1
    idx = k.reshape((1,) * b_axis + (k.shape[0],) + (1,) * (ck.ndim - b_axis - 1))
    idx = idx + jnp.arange(n, dtype=jnp.int32).reshape(
        (1,) * t_axis + (n,) + (1,) * (ck.ndim - t_axis - 1))
    return jnp.take_along_axis(ck, idx, axis=t_axis)


# ---------------------------------------------------------------------------
# paged KV pool primitives
#
# The paged layout replaces each seq-indexed buffer's per-slot row band with
# a shared page pool: ``(num_pages, page_size, rest...)`` leaves plus a
# host-owned ``(B, blocks_per_slot)`` int32 block table mapping each slot's
# logical block to a physical page. Page 0 is the TRASH page — never
# allocated, pinned by the pool — so zeroed block-table rows (freed slots)
# and out-of-capacity pad writes land somewhere harmless instead of
# corrupting live state. Reads gather the slot's pages back into the SAME
# dense ``(B, T, rest)`` view the dense engine attends over; rows backed by
# the trash page are garbage but sit above ``pos`` where the additive
# ``-1e30`` mask drives their softmax weight to exactly 0.0 — the paged
# step is bit-identical to the dense step, not just close.
# ---------------------------------------------------------------------------


def paged_phys_rows(bt: jax.Array, rows: jax.Array, page_size: int) -> jax.Array:
    """Logical rows -> physical flat rows through a block table.

    bt: (lead..., B, nb) int32 page ids; rows: (lead..., B, s) logical
    positions. Rows past capacity (``>= nb * page_size``) map into the
    trash page (page 0) rather than clamping onto a live page.
    """
    nb = bt.shape[-1]
    blk = jnp.clip(rows // page_size, 0, nb - 1)
    page = jnp.take_along_axis(bt, blk, axis=-1)
    phys = page * page_size + rows % page_size
    return jnp.where(rows < nb * page_size, phys, rows % page_size)


def paged_gather(pool: jax.Array, bt: jax.Array, page_size: int) -> jax.Array:
    """Dense per-slot view of a page pool: (P, ps, rest) -> (B, nb*ps, rest).

    The gathered view is exactly the dense cache the non-paged engine
    attends over for rows the slot has written; unbacked rows read the
    trash page and must be mask-invalid (they are: ``row > pos``).
    """
    p, ps = pool.shape[0], page_size
    flat = pool.reshape((p * ps,) + pool.shape[2:])
    idx = bt[..., None] * ps + jnp.arange(ps, dtype=jnp.int32)
    return jnp.take(flat, idx.reshape(bt.shape[0], -1), axis=0)


def paged_update_rows(pool: jax.Array, new: jax.Array, bt: jax.Array,
                      pos: jax.Array, page_size: int) -> jax.Array:
    """Write ``new`` (B, s, rest) at logical rows ``pos..pos+s-1`` through
    the block table. Writes whose block is unallocated (page 0 in the
    table) or past capacity collide on the trash page — harmless, never
    validly read."""
    b, s = new.shape[:2]
    rows = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    phys = paged_phys_rows(bt, rows, page_size)              # (B, s)
    flat = pool.reshape((pool.shape[0] * page_size,) + pool.shape[2:])
    out = flat.at[phys.reshape(-1)].set(
        new.reshape((b * s,) + new.shape[2:]).astype(pool.dtype))
    return out.reshape(pool.shape)


def _gather_rows(buf: jax.Array, idx: jax.Array, axis: int) -> jax.Array:
    """Per-lead-row gather: buf (lead..., N, rest), idx (lead..., m) ->
    (lead..., m, rest). The read dual of ``_scatter_rows``."""
    lead = buf.shape[:axis]
    n = 1
    for d in lead:
        n *= d
    buf2 = buf.reshape((n,) + buf.shape[axis:])
    idx2 = idx.reshape(n, -1)
    out = jax.vmap(lambda bu, ix: jnp.take(bu, ix, axis=0))(buf2, idx2)
    return out.reshape(lead + idx.shape[len(lead):] + buf.shape[axis + 1:])


def paged_rows_snapshot(cache: dict, bt: jax.Array, s: int) -> dict:
    """Paged analogue of ``seq_rows_snapshot``: capture the ``s`` physical
    rows a verify extend will write through the block table.

    ``cache`` holds pool leaves ``(lead..., P, ps, rest)`` plus ``pos``
    ``(lead..., B)``; ``bt`` is ``(B, nb)`` (shared across lead dims).
    """
    pos = jnp.asarray(cache["pos"], jnp.int32)
    rows = pos[..., None] + jnp.arange(s, dtype=jnp.int32)   # (lead..., B, s)
    lead = pos.shape[:-1]
    btb = jnp.broadcast_to(bt, lead + bt.shape)
    snap = {"pos": pos}
    for name, buf in cache.items():
        if name in ("pos", "block_table"):
            continue
        ps = buf.shape[pos.ndim]                             # lead + (P, ps, rest)
        phys = paged_phys_rows(btb, rows, ps)                # (lead..., B, s)
        flat = buf.reshape(lead + (buf.shape[pos.ndim - 1] * ps,) + buf.shape[pos.ndim + 1:])
        snap[name] = _gather_rows(flat, phys, axis=pos.ndim - 1)
    return snap


def paged_rows_restore(cache: dict, snap: dict, bt: jax.Array, keep) -> dict:
    """Rewind a paged cache after a verify pass: restore the rejected chunk
    rows from the snapshot and rewind ``pos`` to ``pos0 + keep``. Pages the
    chunk spilled into stay mapped — the host releases them only at retire."""
    pos0 = snap["pos"]
    keep_f = jnp.broadcast_to(jnp.asarray(keep, jnp.int32), pos0.shape)
    any_buf = next(k for k in snap if k != "pos")
    s = snap[any_buf].shape[pos0.ndim]
    rows = pos0[..., None] + jnp.arange(s, dtype=jnp.int32)
    lead = pos0.shape[:-1]
    btb = jnp.broadcast_to(bt, lead + bt.shape)
    rejected = jnp.arange(s, dtype=jnp.int32) >= keep_f[..., None]
    new = {"pos": pos0 + keep_f}
    for name, buf in cache.items():
        if name in ("pos", "block_table"):
            continue
        ps = buf.shape[pos0.ndim]
        phys = paged_phys_rows(btb, rows, ps)
        flat = buf.reshape(lead + (buf.shape[pos0.ndim - 1] * ps,) + buf.shape[pos0.ndim + 1:])
        cur = _gather_rows(flat, phys, axis=pos0.ndim - 1)
        mask = rejected.reshape(rejected.shape + (1,) * (buf.ndim - pos0.ndim - 1))
        val = jnp.where(mask, snap[name], cur)
        flat2 = _scatter_rows(flat, phys.reshape(lead + (-1,)),
                              val.reshape(lead + (-1,) + flat.shape[pos0.ndim:]),
                              axis=pos0.ndim - 1)
        new[name] = flat2.reshape(buf.shape)
    return new


def reset_slot_pos(cache: Any, slot, value) -> Any:
    """Set every per-slot ``pos`` entry for ``slot`` to ``value``.

    Paged admission prefills directly into the resident grid, so a slot
    that matched ``value`` prefix tokens in the radix cache starts its
    suffix prefill at ``pos = value`` (dense admission instead stages a
    fresh batch-1 cache whose pos starts at 0).
    """
    def one(path, x):
        last = path[-1] if path else None
        if getattr(last, "key", None) == "pos":
            return x.at[..., slot].set(jnp.asarray(value, x.dtype))
        return x
    return jax.tree_util.tree_map_with_path(one, cache)


class StackedCacheMixin:
    """Stacked-cache protocol shared by every registry model.

    Provides ``stack_caches`` / ``cache_at`` / ``write_cache`` on top of the
    model's own ``init_cache``; the per-leaf slot axes are probed lazily on
    first use and memoized (pure Python ints — safe to reuse across jit
    traces, including under donation).
    """

    _slot_axes: Any = None

    def _slot_spec(self):
        if self._slot_axes is None:
            self._slot_axes = probe_slot_axes(self.init_cache)
        return self._slot_axes

    def stack_caches(self, caches: list):
        return stack_caches(self._slot_spec(), caches)

    def cache_at(self, cache, i):
        return cache_at(self._slot_spec(), cache, i)

    def write_cache(self, cache, sub, i):
        return write_cache(self._slot_spec(), cache, sub, i)

    def cache_pspecs(self, cache, mesh, data_axis: str = "data"):
        """Mesh placement for a stacked cache: slot axis over ``data_axis``.

        Inherited by every registry family — dense KV, MLA latents,
        ring+recurrent, conv/SSD — because the slot axes are probed, not
        hand-annotated. The serving engine uses this both to ``device_put``
        the initial grid and to pin the cache output sharding inside its
        jitted decode/extend/verify/rewind steps.
        """
        return slot_pspecs(self._slot_spec(), cache, mesh, data_axis)

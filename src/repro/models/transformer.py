"""Generic decoder-only transformer LM.

Covers the dense GQA/MHA families (qwen2.5-32b, phi4-mini, nemotron-4,
codeqwen1.5) and the modality-stub backbones (musicgen-large [audio],
qwen2-vl-2b [vlm] with M-RoPE). Layer params are stacked (L, ...) and the
stack is lax.scan'ed (HLO stays small for 64-layer archs; the roofline
harness corrects loop trip counts — see benchmarks/roofline.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cascade
from repro.core.cascade import CascadeConfig
from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain_residual
from repro.models import layers as L
from repro.models.cache_utils import (StackedCacheMixin, paged_rows_restore,
                                      paged_rows_snapshot, seq_rows_restore,
                                      seq_rows_snapshot, take_last_valid)


def _remat_policy(name: str):
    import jax as _jax
    return {
        "dots": _jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "none": _jax.checkpoint_policies.nothing_saveable,
        "save_all": _jax.checkpoint_policies.everything_saveable,
    }[name]


class TransformerLM(StackedCacheMixin):
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.attn_cfg = L.AttnConfig(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias,
            rope_theta=cfg.rope_theta,
            rope_fraction=cfg.rope_fraction,
            window=cfg.window,
            mrope_sections=cfg.mrope_sections,
            q_chunk=cfg.q_chunk,
        )
        # windowed archs hold O(window) ring state, so the serving context
        # length is unbounded by the cache (engine admission checks this)
        self.unbounded_context = self.attn_cfg.window > 0

    # ------------------------------------------------------------------ init
    def _layer_init(self, key: jax.Array, ccfg: CascadeConfig) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_init(cfg.d_model, cfg.norm_type),
            "attn": L.attn_init(k1, self.attn_cfg, ccfg),
            "ln2": L.norm_init(cfg.d_model, cfg.norm_type),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, ccfg),
        }

    def init_params(self, key: jax.Array, ccfg: CascadeConfig) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.n_layers + 2)
        params = {
            "layers": jax.vmap(lambda k: self._layer_init(k, ccfg))(keys[: cfg.n_layers]),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm_type),
        }
        if not cfg.input_embeds:
            params["embed"] = L.embed_init(keys[-2], cfg.vocab, cfg.d_model, dtype=ccfg.compute_dtype)
        head_width = cfg.vocab * max(1, cfg.n_codebooks)
        if cfg.tie_embeddings and not cfg.input_embeds:
            pass  # logits via embed.T
        else:
            params["lm_head"] = cascade.linear_init(keys[-1], cfg.d_model, head_width, ccfg)
        return params

    # --------------------------------------------------------------- forward
    def _embed(self, params: dict, batch: dict, ccfg: CascadeConfig) -> jax.Array:
        cfg = self.cfg
        if cfg.input_embeds:
            x = batch["inputs_embeds"].astype(ccfg.compute_dtype)
        else:
            x = L.embed_apply(params["embed"], batch["tokens"])
        if cfg.rope_fraction == 0.0:  # sinusoidal-position archs (musicgen)
            s = x.shape[1]
            pos0 = batch.get("pos_offset", 0)
            x = x + L.sinusoidal_positions(s, cfg.d_model, pos0)[None].astype(x.dtype)
        return x

    def _head(self, params: dict, x: jax.Array, ccfg: CascadeConfig) -> jax.Array:
        cfg = self.cfg
        x = L.norm_apply(params["final_norm"], x, cfg.norm_type)
        if cfg.tie_embeddings and not cfg.input_embeds:
            logits = jnp.dot(x.astype(ccfg.compute_dtype), params["embed"]["table"].T,
                             preferred_element_type=jnp.float32)
        else:
            logits = cascade.linear_apply(params["lm_head"], x, ccfg)
        if cfg.n_codebooks:
            b, s, _ = logits.shape
            logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab)
        return logits.astype(jnp.float32)

    def _block(self, lp: dict, x: jax.Array, ccfg: CascadeConfig,
               positions, cache, mode: str, max_len: int | None = None,
               n_valid=None):
        cfg = self.cfg
        h, new_cache = L.attn_apply(
            lp["attn"], L.norm_apply(lp["ln1"], x, cfg.norm_type),
            self.attn_cfg, ccfg, positions=positions, cache=cache, mode=mode,
            max_len=max_len, n_valid=n_valid)
        x = x + h
        x = x + L.mlp_apply(lp["mlp"], L.norm_apply(lp["ln2"], x, cfg.norm_type),
                            cfg.mlp_kind, ccfg)
        return constrain_residual(x), new_cache

    def forward(self, params: dict, batch: dict, ccfg: CascadeConfig,
                remat: bool = False, remat_policy: str = "dots") -> jax.Array:
        """Full-sequence forward (train / no-cache eval)."""
        cfg = self.cfg
        x = self._embed(params, batch, ccfg)
        positions = batch.get("positions")

        def body(x, lp):
            y, _ = self._block(lp, x, ccfg, positions, None, "full")
            return y, None

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(remat_policy))
        if cfg.scan_layers:
            x, _ = lax.scan(body, x, params["layers"])
        else:
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, _ = body(x, lp)
        return self._head(params, x, ccfg)

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        one = lambda _: L.attn_cache_init(batch, max_len, self.attn_cfg, dtype)
        return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers))}

    # ------------------------------------------------------- paged cache API
    @property
    def paged_attention(self) -> bool:
        """Full-attention archs page; ring state is O(window) and per-slot
        (nothing to share), multi-codebook grids serve slot-wise anyway."""
        return self.attn_cfg.window == 0 and not self.cfg.n_codebooks

    def init_paged_cache(self, batch: int, num_pages: int, page_size: int,
                         dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        one = lambda _: L.attn_cache_init_paged(batch, num_pages, page_size,
                                                self.attn_cfg, dtype)
        return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers))}

    def paged_copy_page(self, cache: dict, src, dst) -> dict:
        """Copy physical page ``src`` to ``dst`` in every pool leaf — the
        device half of radix-cache copy-on-write (a partially matched
        shared page is cloned before the new stream writes into it)."""
        out = {}
        for name, buf in cache["layers"].items():
            out[name] = buf if name == "pos" else buf.at[:, dst].set(buf[:, src])
        return {"layers": out}

    def prefill(self, params: dict, batch: dict, ccfg: CascadeConfig,
                max_len: int | None = None):
        cfg = self.cfg
        x = self._embed(params, batch, ccfg)
        positions = batch.get("positions")

        def body(x, lp):
            y, c = self._block(lp, x, ccfg, positions, None, "prefill", max_len=max_len)
            return y, c

        x, caches = lax.scan(body, x, params["layers"])
        logits = self._head(params, x[:, -1:], ccfg)
        return logits, {"layers": caches}

    def decode_step(self, params: dict, batch: dict, cache: dict, ccfg: CascadeConfig):
        cfg = self.cfg
        x = self._embed(params, batch, ccfg)
        positions = batch.get("positions")
        bt = batch.get("block_table")

        def body(x, scanned):
            lp, c = scanned
            if bt is not None:
                c = dict(c, block_table=bt)
            y, nc = self._block(lp, x, ccfg, positions, c, "decode")
            return y, nc

        x, new_caches = lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = self._head(params, x, ccfg)
        return logits, {"layers": new_caches}

    # ----------------------------------------- continuous batching cache API
    # ``stack_caches``/``cache_at``/``write_cache`` come from
    # StackedCacheMixin: the serving engine keeps ONE fixed-shape cache for
    # the whole slot grid and admits/retires requests as slot writes, so
    # batched decode never recompiles as traffic comes and goes.

    def prefill_extend(self, params: dict, batch: dict, cache: dict,
                       ccfg: CascadeConfig, n_valid=None, all_logits: bool = False):
        """Append a (possibly right-padded) token chunk to an existing cache.

        Chunked-prefill admission path: the chunk shape stays fixed so long
        prompts compile ONE extend kernel regardless of length; only the
        first ``n_valid`` tokens of the chunk are real (full attention:
        pad K/V lands mask-invalid above each row's position; ring buffers:
        pad writes are dropped). Returns logits for the last valid token,
        (B, 1, V) — or for every chunk position, (B, S, V), when
        ``all_logits`` is set (the speculative-decode verify pass).
        """
        x = self._embed(params, batch, ccfg)
        b, s, _ = x.shape
        nv = jnp.asarray(s if n_valid is None else n_valid, jnp.int32)
        bt = batch.get("block_table")

        def body(x, scanned):
            lp, c = scanned
            if bt is not None:
                c = dict(c, block_table=bt)
            y, nc = self._block(lp, x, ccfg, None, c, "extend", n_valid=nv)
            return y, nc

        x, new_caches = lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = self._head(params, x if all_logits else take_last_valid(x, nv), ccfg)
        return logits, {"layers": new_caches}

    # --------------------------------------------------- speculative decode
    def spec_verify(self, params: dict, batch: dict, cache: dict,
                    ccfg: CascadeConfig):
        """Score a (B, 1+K) draft chunk in ONE extend pass: per-position
        logits (B, 1+K, V), the advanced cache, and a rewind checkpoint
        (the KV rows the chunk overwrites — for ring buffers those are live
        in-window entries that a rejection must restore)."""
        bt = batch.get("block_table")
        s = batch["tokens"].shape[1]
        if bt is not None:
            ckpt = {"layers": paged_rows_snapshot(cache["layers"], bt, s),
                    "block_table": bt}
        else:
            ckpt = {"layers": seq_rows_snapshot(cache["layers"], s)}
        logits, cache = self.prefill_extend(params, batch, cache, ccfg,
                                            all_logits=True)
        return logits, cache, ckpt

    def spec_rewind(self, cache: dict, ckpt: dict, keep) -> dict:
        """Per-slot rewind after a verify pass: the first ``keep[b]`` chunk
        tokens stay committed, the rejected suffix rows are restored and
        ``pos`` rewinds to ``pos0 + keep[b]``. Paged checkpoints carry the
        block table the verify wrote through; pages the chunk spilled into
        stay mapped (the host releases them at retire)."""
        bt = ckpt.get("block_table")
        if bt is not None:
            return {"layers": paged_rows_restore(cache["layers"],
                                                 ckpt["layers"], bt, keep)}
        return {"layers": seq_rows_restore(cache["layers"], ckpt["layers"], keep)}

"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local-attention
hybrid, block pattern (R, R, A) repeating.

Prefill runs the RG-LRU as a log-depth ``associative_scan`` (sub-quadratic —
this arch runs the long_500k shape); decode is the O(1) recurrence.
The 26 layers = 8 scanned (R, R, A) groups + a trailing (R, R) pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cascade
from repro.core.cascade import CascadeConfig
from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain_residual
from repro.models import layers as L


def _remat_policy(name: str):
    import jax as _jax
    return {
        "dots": _jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "none": _jax.checkpoint_policies.nothing_saveable,
        "save_all": _jax.checkpoint_policies.everything_saveable,
    }[name]
from repro.models.cache_utils import (StackedCacheMixin, seq_rows_restore,
                                      seq_rows_snapshot, slice_rows_per_slot,
                                      take_last_valid)
from repro.models.ssm import _causal_conv, _conv_decode, _conv_extend, conv_prefill_state

_C = 8.0  # RG-LRU gate sharpness constant (Griffin paper)


class GriffinLM(StackedCacheMixin):
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.lru = cfg.lru_width or cfg.d_model
        self.attn_cfg = L.AttnConfig(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            window=cfg.window,
            q_chunk=cfg.q_chunk,
        )
        pat = cfg.block_pattern or ("R", "R", "A")
        self.group = pat
        self.n_groups = cfg.n_layers // len(pat)
        self.n_tail = cfg.n_layers - self.n_groups * len(pat)  # trailing R's
        # windowed attention + O(1) recurrent state: serving context length
        # is unbounded by the cache (engine admission checks this)
        self.unbounded_context = self.attn_cfg.window > 0

    # ------------------------------------------------------------------ init
    def _rblock_init(self, key, ccfg):
        cfg = self.cfg
        ks = jax.random.split(key, 7)
        lru = self.lru
        return {
            "ln": L.norm_init(cfg.d_model, cfg.norm_type),
            "w_in": cascade.linear_init(ks[0], cfg.d_model, lru, ccfg),
            "w_gate": cascade.linear_init(ks[1], cfg.d_model, lru, ccfg),
            "conv_w": jax.random.normal(ks[2], (cfg.conv_width, lru), jnp.float32) * 0.1,
            "conv_b": jnp.zeros((lru,), jnp.float32),
            "wa": cascade.linear_init(ks[3], lru, lru, ccfg, use_bias=True),
            "wx": cascade.linear_init(ks[4], lru, lru, ccfg, use_bias=True),
            "lam": jnp.linspace(2.0, 5.0, lru).astype(jnp.float32),  # softplus^-1(a) init
            "w_out": cascade.linear_init(ks[5], lru, cfg.d_model, ccfg),
            "ln2": L.norm_init(cfg.d_model, cfg.norm_type),
            "mlp": L.mlp_init(ks[6], cfg.d_model, cfg.d_ff, cfg.mlp_kind, ccfg),
        }

    def _ablock_init(self, key, ccfg):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln": L.norm_init(cfg.d_model, cfg.norm_type),
            "attn": L.attn_init(k1, self.attn_cfg, ccfg),
            "ln2": L.norm_init(cfg.d_model, cfg.norm_type),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, ccfg),
        }

    def _group_init(self, key, ccfg):
        ks = jax.random.split(key, len(self.group))
        out = {}
        for i, kind in enumerate(self.group):
            out[f"b{i}"] = self._rblock_init(ks[i], ccfg) if kind == "R" else self._ablock_init(ks[i], ccfg)
        return out

    def init_params(self, key, ccfg):
        cfg = self.cfg
        keys = jax.random.split(key, self.n_groups + self.n_tail + 2)
        params = {
            "groups": jax.vmap(lambda k: self._group_init(k, ccfg))(keys[: self.n_groups]),
            "tail": [self._rblock_init(keys[self.n_groups + i], ccfg) for i in range(self.n_tail)],
            "final_norm": L.norm_init(cfg.d_model, cfg.norm_type),
            "embed": L.embed_init(keys[-2], cfg.vocab, cfg.d_model, dtype=ccfg.compute_dtype),
            "lm_head": cascade.linear_init(keys[-1], cfg.d_model, cfg.vocab, ccfg),
        }
        return params

    # --------------------------------------------------------------- RG-LRU
    def _rglru(self, lp, y, ccfg, h0=None, mode="full", n_valid=None):
        """y: (b, s, lru) post-conv input. Returns (out, h_last, h_all) with
        ``h_all`` the f32 state after EVERY step, (b, s, lru) — the
        speculative-rewind checkpoint stack (None in decode mode). In
        ``extend`` mode only the first ``n_valid`` steps are real: pad steps
        are forced to the identity recurrence (a=1, input=0) so the carried
        state lands exactly on the n_valid boundary."""
        r = jax.nn.sigmoid(cascade.linear_apply(lp["wa"], y, ccfg).astype(jnp.float32))
        i = jax.nn.sigmoid(cascade.linear_apply(lp["wx"], y, ccfg).astype(jnp.float32))
        log_a = -_C * r * jax.nn.softplus(lp["lam"])        # (b,s,lru) <= 0
        if mode == "decode":
            a = jnp.exp(log_a)
            gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * y.astype(jnp.float32))
            h = a[:, 0] * h0 + gated[:, 0]
            return h[:, None].astype(y.dtype), h, None
        if n_valid is not None:
            m = (jnp.arange(y.shape[1]) < n_valid)[None, :, None]
            log_a = jnp.where(m, log_a, 0.0)                # pad: a = exp(0) = 1
        a = jnp.exp(log_a)
        gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * y.astype(jnp.float32))
        if n_valid is not None:
            gated = jnp.where(m, gated, 0.0)                # pad: zero input
        # associative scan: h_t = a_t h_{t-1} + g_t
        def combine(c1, c2):
            a1, g1 = c1
            a2, g2 = c2
            return a1 * a2, g1 * a2 + g2
        aa, hh = lax.associative_scan(combine, (a, gated), axis=1)
        if h0 is not None:
            hh = hh + aa * h0[:, None]
        return hh.astype(y.dtype), hh[:, -1], hh

    def _rblock(self, lp, x, ccfg, cache=None, mode="full", n_valid=None,
                collect: bool = False):
        cfg = self.cfg
        ckpt = None
        u = L.norm_apply(lp["ln"], x, cfg.norm_type)
        gate = jax.nn.gelu(cascade.linear_apply(lp["w_gate"], u, ccfg).astype(jnp.float32))
        y = cascade.linear_apply(lp["w_in"], u, ccfg)
        if mode == "decode":
            y_c, new_conv = _conv_decode(y, cache["conv"], lp["conv_w"], lp["conv_b"])
            out, h_last, _ = self._rglru(lp, y_c, ccfg, cache["h"], mode)
            new_cache = {"conv": new_conv, "h": h_last}
        elif mode == "extend":
            y_c, new_conv, conv_full = _conv_extend(y, cache["conv"], lp["conv_w"],
                                                    lp["conv_b"], n_valid)
            out, h_last, h_all = self._rglru(lp, y_c, ccfg, cache["h"], mode, n_valid)
            new_cache = {"conv": new_conv, "h": h_last}
            if collect:
                # checkpoint stacks: state after j chunk tokens is
                # conv_full[:, j:j+w-1] / h[:, j] (index 0 = pre-chunk state)
                ckpt = {"conv": conv_full,
                        "h": jnp.concatenate([cache["h"][:, None], h_all], axis=1)}
        else:
            y_c = _causal_conv(y, lp["conv_w"], lp["conv_b"])
            out, h_last, _ = self._rglru(lp, y_c, ccfg, None, mode)
            new_cache = ({"conv": conv_prefill_state(y, cfg.conv_width), "h": h_last}
                         if mode == "prefill" else None)
        mixed = cascade.linear_apply(lp["w_out"], (out.astype(jnp.float32) * gate).astype(x.dtype), ccfg)
        x = x + mixed
        x = x + L.mlp_apply(lp["mlp"], L.norm_apply(lp["ln2"], x, cfg.norm_type), cfg.mlp_kind, ccfg)
        x = constrain_residual(x)
        if collect:
            return x, new_cache, ckpt
        return x, new_cache

    def _ablock(self, lp, x, ccfg, cache=None, mode="full", max_len=None, n_valid=None,
                collect: bool = False):
        cfg = self.cfg
        # the rewind checkpoint for a ring-KV block is the set of rows the
        # chunk will overwrite — snapshot BEFORE the write
        ckpt = seq_rows_snapshot(cache, x.shape[1]) if collect else None
        h, nc = L.attn_apply(lp["attn"], L.norm_apply(lp["ln"], x, cfg.norm_type),
                             self.attn_cfg, ccfg, cache=cache, mode=mode, max_len=max_len,
                             n_valid=n_valid)
        x = x + h
        x = x + L.mlp_apply(lp["mlp"], L.norm_apply(lp["ln2"], x, cfg.norm_type), cfg.mlp_kind, ccfg)
        x = constrain_residual(x)
        if collect:
            return x, nc, ckpt
        return x, nc

    def _group_apply(self, gp, x, ccfg, gcache=None, mode="full", max_len=None,
                     n_valid=None, collect: bool = False):
        new_cache, ckpts = {}, {}
        for i, kind in enumerate(self.group):
            c = gcache[f"b{i}"] if gcache is not None else None
            if kind == "R":
                r = self._rblock(gp[f"b{i}"], x, ccfg, c, mode, n_valid, collect)
            else:
                r = self._ablock(gp[f"b{i}"], x, ccfg, c, mode, max_len, n_valid,
                                 collect)
            if collect:
                x, new_cache[f"b{i}"], ckpts[f"b{i}"] = r
            else:
                x, new_cache[f"b{i}"] = r
        if collect:
            return x, new_cache, ckpts
        return x, new_cache

    # --------------------------------------------------------------- api
    def _head(self, params, x, ccfg):
        x = L.norm_apply(params["final_norm"], x, self.cfg.norm_type)
        return cascade.linear_apply(params["lm_head"], x, ccfg).astype(jnp.float32)

    def forward(self, params, batch, ccfg, remat: bool = False,
                remat_policy: str = "dots"):
        x = L.embed_apply(params["embed"], batch["tokens"])

        def body(x, gp):
            y, _ = self._group_apply(gp, x, ccfg, None, "full")
            return y, None

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(remat_policy))
        x, _ = lax.scan(body, x, params["groups"])
        for tp in params["tail"]:
            x, _ = self._rblock(tp, x, ccfg, None, "full")
        return self._head(params, x, ccfg)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        lru = self.lru

        def rcache(_):
            return {"conv": jnp.zeros((batch, cfg.conv_width - 1, lru), dtype),
                    "h": jnp.zeros((batch, lru), jnp.float32)}  # recurrence stays f32

        def gcache(_):
            out = {}
            for i, kind in enumerate(self.group):
                out[f"b{i}"] = (rcache(None) if kind == "R"
                                else L.attn_cache_init(batch, max_len, self.attn_cfg, dtype))
            return out

        return {
            "groups": jax.vmap(gcache)(jnp.arange(self.n_groups)),
            "tail": [rcache(None) for _ in range(self.n_tail)],
        }

    def prefill(self, params, batch, ccfg, max_len: int | None = None):
        x = L.embed_apply(params["embed"], batch["tokens"])

        def body(x, gp):
            y, c = self._group_apply(gp, x, ccfg, None, "prefill", max_len)
            return y, c

        x, gcaches = lax.scan(body, x, params["groups"])
        tail_caches = []
        for tp in params["tail"]:
            x, tc = self._rblock(tp, x, ccfg, None, "prefill")
            tail_caches.append(tc)
        logits = self._head(params, x[:, -1:], ccfg)
        return logits, {"groups": gcaches, "tail": tail_caches}

    def decode_step(self, params, batch, cache, ccfg):
        x = L.embed_apply(params["embed"], batch["tokens"])

        def body(x, scanned):
            gp, c = scanned
            y, nc = self._group_apply(gp, x, ccfg, c, "decode")
            return y, nc

        x, new_g = lax.scan(body, x, (params["groups"], cache["groups"]))
        new_tail = []
        for tp, tc in zip(params["tail"], cache["tail"]):
            x, nc = self._rblock(tp, x, ccfg, tc, "decode")
            new_tail.append(nc)
        logits = self._head(params, x, ccfg)
        return logits, {"groups": new_g, "tail": new_tail}

    def prefill_extend(self, params, batch, cache, ccfg, n_valid=None):
        """Append a (right-padded) token chunk to an existing cache: the
        local-attention ring buffers advance without ever clobbering
        in-window entries, and RG-LRU/conv state carries across chunks
        (pad steps are identity). This is how windowed archs chunk-prefill
        arbitrarily long prompts through a fixed-shape kernel. Returns
        logits for the last valid token, (B, 1, V)."""
        x = L.embed_apply(params["embed"], batch["tokens"])
        b, s = batch["tokens"].shape
        nv = jnp.asarray(s if n_valid is None else n_valid, jnp.int32)

        def body(x, scanned):
            gp, c = scanned
            y, nc = self._group_apply(gp, x, ccfg, c, "extend", n_valid=nv)
            return y, nc

        x, new_g = lax.scan(body, x, (params["groups"], cache["groups"]))
        new_tail = []
        for tp, tc in zip(params["tail"], cache["tail"]):
            x, nc = self._rblock(tp, x, ccfg, tc, "extend", n_valid=nv)
            new_tail.append(nc)
        logits = self._head(params, take_last_valid(x, nv), ccfg)
        return logits, {"groups": new_g, "tail": new_tail}

    # --------------------------------------------------- speculative decode
    def spec_verify(self, params, batch, cache, ccfg):
        """Score a (B, 1+K) draft chunk in ONE extend pass. The checkpoint
        carries, per block, what a rejected suffix needs to roll back: the
        overwritten ring-KV rows for attention blocks, and per-position
        {conv window, RG-LRU h} stacks for recurrent blocks (the RG-LRU's
        associative scan already computes every intermediate state)."""
        x = L.embed_apply(params["embed"], batch["tokens"])

        def body(x, scanned):
            gp, c = scanned
            y, nc, ck = self._group_apply(gp, x, ccfg, c, "extend", collect=True)
            return y, (nc, ck)

        x, (new_g, ck_g) = lax.scan(body, x, (params["groups"], cache["groups"]))
        new_tail, ck_tail = [], []
        for tp, tc in zip(params["tail"], cache["tail"]):
            x, nc, ck = self._rblock(tp, x, ccfg, tc, "extend", collect=True)
            new_tail.append(nc)
            ck_tail.append(ck)
        logits = self._head(params, x, ccfg)
        return (logits, {"groups": new_g, "tail": new_tail},
                {"groups": ck_g, "tail": ck_tail})

    def _rblock_rewind(self, cache, ck, keep, b_axis):
        """Select the checkpointed {conv, h} state at the accept boundary."""
        w = self.cfg.conv_width
        conv = slice_rows_per_slot(ck["conv"], keep, b_axis, w - 1)
        h = slice_rows_per_slot(ck["h"], keep, b_axis, 1)
        h = jnp.squeeze(h, axis=b_axis + 1)
        return {"conv": conv.astype(cache["conv"].dtype), "h": h}

    def spec_rewind(self, cache, ckpt, keep):
        """Per-slot rewind: restore rejected ring-KV rows + rewind pos for
        attention blocks, select recurrent checkpoints for R blocks."""
        new_groups = {}
        for i, kind in enumerate(self.group):
            c, ck = cache["groups"][f"b{i}"], ckpt["groups"][f"b{i}"]
            if kind == "R":
                new_groups[f"b{i}"] = self._rblock_rewind(c, ck, keep, b_axis=1)
            else:
                new_groups[f"b{i}"] = seq_rows_restore(c, ck, keep)
        new_tail = [self._rblock_rewind(c, ck, keep, b_axis=0)
                    for c, ck in zip(cache["tail"], ckpt["tail"])]
        return {"groups": new_groups, "tail": new_tail}

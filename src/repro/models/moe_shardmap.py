"""Expert-parallel MoE dispatch via shard_map (beyond-paper §Perf item).

Problem (measured, EXPERIMENTS.md appendix): the jit/GSPMD capacity-dispatch
scatter cannot lower as an all-to-all — the partitioner all-reduces the whole
(E·C, d) dispatch buffer across data shards (deepseek-v2 prefill: 1,069 GB/
device/step).

Fix exploited here: under the CASCADE/TP layout, *tokens are replicated over
the model axis* (batch shards over data) while *experts are sharded over
model*. So no token movement is needed at all: each model rank selects the
assignments routed to ITS local experts, computes them, and the combine is a
single psum of gate-weighted (T_local, d) outputs over the model axis —
activation-sized, not buffer-sized.

Per-layer collective: T_local · d · 4 B (one all-reduce), vs the GSPMD path's
(E·C·d + T·k·d) — ~10× less for olmoe, more for deepseek.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.cascade import CascadeConfig
from repro.configs.base import ArchConfig


def _local_moe(xf, router, *expert_args, cfg: ArchConfig, ccfg: CascadeConfig,
               model_axis: str, cap: int, fp4: bool):
    """Runs on ONE model rank: xf (T_loc, d) [same on every rank]; expert
    weights (dense or FP4 codes+scales) are this rank's local slice."""
    if fp4:
        from repro.core import quant
        cg, csg, cu, csu, cd, csd = expert_args
        deq = jax.vmap(lambda c, sc: quant.dequantize_weight(c, sc, ccfg.compute_dtype))
        wg, wu, wd = deq(cg, csg), deq(cu, csu), deq(cd, csd)
    else:
        wg, wu, wd = expert_args
    t, d = xf.shape
    e_loc = wg.shape[0]
    k = cfg.moe_top_k
    rank = lax.axis_index(model_axis)

    logits = jnp.dot(xf.astype(jnp.float32), router)            # (T, E) global
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, k)                            # (T, k) global ids
    if cfg.moe_renorm:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)                                    # (T*k,)
    local_id = flat_e - rank * e_loc                            # position among local experts
    mine = (local_id >= 0) & (local_id < e_loc)
    local_id = jnp.where(mine, local_id, 0)

    onehot = jax.nn.one_hot(local_id, e_loc, dtype=jnp.int32) * mine[:, None].astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1, local_id[:, None], 1)[:, 0]
    keep = mine & (pos < cap)
    dst = jnp.where(keep, local_id * cap + pos, e_loc * cap)    # OOB = drop

    xk = jnp.repeat(xf, k, axis=0)
    buf = jnp.zeros((e_loc * cap, d), xf.dtype).at[dst].add(xk, mode="drop")
    buf = buf.reshape(e_loc, cap, d)

    def ff(w, x):  # dense expert weights (shard_map works on raw arrays)
        return jnp.einsum("ecd,edf->ecf", x.astype(ccfg.compute_dtype),
                          w.astype(ccfg.compute_dtype),
                          preferred_element_type=jnp.float32).astype(ccfg.compute_dtype)

    h = jax.nn.silu(ff(wg, buf).astype(jnp.float32))
    h = (h * ff(wu, buf).astype(jnp.float32)).astype(buf.dtype)
    out = ff(wd, h).reshape(e_loc * cap, d)

    got = jnp.take(out, jnp.minimum(dst, e_loc * cap - 1), axis=0)
    got = jnp.where(keep[:, None], got, 0.0)
    y_partial = jnp.sum((got.astype(jnp.float32)
                         * gates.reshape(-1)[:, None]).reshape(t, k, d), axis=1)
    # combine: each rank contributed only its local experts' outputs
    return lax.psum(y_partial, model_axis)


def moe_ffn_apply_ep(params: dict, x: jax.Array, cfg: ArchConfig,
                     ccfg: CascadeConfig, mesh, model_axis: str = "model",
                     batch_axes=("pod", "data")) -> jax.Array:
    """shard_map expert-parallel MoE FFN. x: (B, S, d); expert weights in
    ``params`` are dense ('train'/'bf16' mode) and sharded (E over model)."""
    b, s, d = x.shape
    baxes = tuple(a for a in batch_axes if a in mesh.shape)
    baxis = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    data_size = 1
    for a in baxes:
        data_size *= mesh.shape[a]
    t_local = (b * s) // max(data_size, 1)
    cap = max(8, -(-math.ceil(cfg.moe_capacity_factor * t_local * cfg.moe_top_k
                              / cfg.n_experts) // 8) * 8)

    fp4 = "codes" in params["wg"]
    if fp4:
        expert_args = (params["wg"]["codes"], params["wg"]["scale"],
                       params["wu"]["codes"], params["wu"]["scale"],
                       params["wd"]["codes"], params["wd"]["scale"])
    else:
        expert_args = (params["wg"]["w"], params["wu"]["w"], params["wd"]["w"])

    fn = functools.partial(_local_moe, cfg=cfg, ccfg=ccfg,
                           model_axis=model_axis, cap=cap, fp4=fp4)
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P(baxis, None),            # tokens: data-sharded, model-replicated
                  P(None, None))             # router replicated
                 + (P(model_axis, None, None),) * len(expert_args),  # EP weights
        out_specs=P(baxis, None),
        check_rep=False)

    xf = x.reshape(b * s, d)
    y = mapped(xf, params["router"], *expert_args)

    if "shared" in params:
        from repro.models import layers as L
        y = y + L.mlp_apply(params["shared"], xf, "swiglu", ccfg).astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype)

"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) LM.

Train/prefill use the **chunked dual form** (matmul-dominant: intra-chunk
quadratic term + inter-chunk state carry over a short lax.scan) so FLOPs land
on the MXU and the attention-free arch stays sub-quadratic: O(S * chunk) +
O(S * state). Decode is the O(1) recurrence. The SSD scan kernel in
``repro.kernels.ssd_scan``/ref mirrors the sequential recurrence as oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cascade
from repro.core.cascade import CascadeConfig
from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain_residual
from repro.models import layers as L
from repro.models.cache_utils import (StackedCacheMixin, slice_rows_per_slot,
                                      take_last_valid)


def _remat_policy(name: str):
    import jax as _jax
    return {
        "dots": _jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "none": _jax.checkpoint_policies.nothing_saveable,
        "save_all": _jax.checkpoint_policies.everything_saveable,
    }[name]


def ssd_chunked(x, dt, A, B, C, D, chunk: int, initial_state=None,
                return_chunk_states: bool = False):
    """Chunked SSD. x: (b,s,h,p); dt: (b,s,h) (post-softplus); A: (h,) (<0);
    B, C: (b,s,g,n); D: (h,). Returns (y: (b,s,h,p), final_state: (b,h,p,n)).

    ``return_chunk_states`` additionally returns the state BEFORE each chunk
    (b, nc, h, p, n) — with chunk=1 that is the state after every token, the
    per-position checkpoint stack speculative decode rewinds onto.
    """
    b, s_orig, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hb = h // g
    q = min(chunk, s_orig)
    pad = (-s_orig) % q
    if pad:
        # zero-padded steps have dt=0 => decay exp(0)=1 and zero input:
        # the state passes through unchanged; padded outputs are sliced off.
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = zp(x), zp(dt), zp(B), zp(C)
    s = s_orig + pad
    nc = s // q

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Bh = jnp.repeat(B.astype(jnp.float32), hb, axis=2).reshape(b, nc, q, h, n)
    Ch = jnp.repeat(C.astype(jnp.float32), hb, axis=2).reshape(b, nc, q, h, n)

    dtA = dtf * A  # (b,nc,q,h)
    cum = jnp.cumsum(dtA, axis=2)  # inclusive within chunk

    # --- intra-chunk (quadratic in q) ---
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (b,nc,qi,qj,h)
    ii = jnp.arange(q)
    causal = ii[:, None] >= ii[None, :]
    # mask BEFORE exp: exp of the (positive) j>i entries overflows to inf and
    # where-of-inf poisons gradients with NaN
    LL = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -1e30))
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)                # (b,nc,qi,qj,h)
    scores = CB * LL * dtf[:, :, None, :, :]                     # * dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xf)

    # --- chunk states ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)              # (b,nc,q,h)
    S = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", decay_to_end * dtf, Bh, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (b,nc,h)

    # --- inter-chunk carry (short scan over nc) ---
    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

    def step(state, inp):
        dcy, s_c = inp  # (b,h), (b,h,p,n)
        new = state * dcy[:, :, None, None] + s_c
        return new, state  # emit the state *before* this chunk

    final_state, states_prev = lax.scan(
        step, state0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S, 1, 0)))
    states_prev = jnp.moveaxis(states_prev, 0, 1)                # (b,nc,h,p,n)

    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(cum), Ch, states_prev)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    if D is not None:
        y = y + D[None, None, :, None] * x.astype(jnp.float32)
    y = y[:, :s_orig].astype(x.dtype)
    if return_chunk_states:
        return y, final_state, states_prev
    return y, final_state


def ssd_decode_step(x, dt, A, B, C, D, state):
    """Single-token recurrence. x: (b,1,h,p); dt: (b,1,h); B/C: (b,1,g,n);
    state: (b,h,p,n). Returns (y: (b,1,h,p), new_state)."""
    b, _, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hb = h // g
    xf = x[:, 0].astype(jnp.float32)                     # (b,h,p)
    dtf = dt[:, 0].astype(jnp.float32)                   # (b,h)
    Bh = jnp.repeat(B[:, 0].astype(jnp.float32), hb, axis=1)  # (b,h,n)
    Ch = jnp.repeat(C[:, 0].astype(jnp.float32), hb, axis=1)
    decay = jnp.exp(dtf * A)                             # (b,h)
    new_state = state * decay[:, :, None, None] + \
        (dtf[:, :, None] * xf)[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    if D is not None:
        y = y + D[None, :, None] * xf
    return y[:, None].astype(x.dtype), new_state


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds. x: (b,s,dim); w: (width,dim)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    s = x.shape[1]
    y = sum(xp[:, i:i + s] * w[i] for i in range(width))
    return y + b


def _conv_decode(x, conv_state, w, b):
    """x: (b,1,dim); conv_state: (b,width-1,dim) holding previous inputs.
    The cache may be stored in fp8 (kv_dtype); compute in x.dtype and store
    back in the cache dtype."""
    full = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (b,width,dim)
    y = jnp.einsum("bwd,wd->bd", full.astype(jnp.float32), w.astype(jnp.float32)) + b
    new_state = full[:, 1:].astype(conv_state.dtype)
    return y[:, None].astype(x.dtype), new_state


def _conv_extend(x, conv_state, w, b, n_valid=None):
    """Causal conv over a chunk with carried state (chunked-prefill path).

    x: (b,s,dim) raw conv inputs, only the first ``n_valid`` real;
    conv_state: (b,width-1,dim) previous raw inputs. Returns the conv
    outputs for the chunk, the state advanced to the ``n_valid`` boundary
    (so right-padding never leaks into the carry), and the full raw input
    window (b, width-1+s, dim) — the conv state after j chunk tokens is
    ``full[:, j:j+width-1]``, which is the speculative-rewind checkpoint."""
    width = w.shape[0]
    s = x.shape[1]
    full = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (b,w-1+s,dim)
    y = sum(full[:, i:i + s] * w[i] for i in range(width)) + b
    nv = s if n_valid is None else n_valid
    new_state = lax.dynamic_slice_in_dim(full, nv, width - 1, axis=1)
    return y, new_state.astype(conv_state.dtype), full


def conv_prefill_state(x_raw, width: int):
    """Last ``width-1`` raw conv inputs after a whole-prompt prefill,
    left-padded with zeros (the implicit causal-conv padding) when the
    prompt is shorter than the conv receptive field."""
    pad = max(0, (width - 1) - x_raw.shape[1])
    if pad:
        x_raw = jnp.pad(x_raw, ((0, 0), (pad, 0), (0, 0)))
    return x_raw[:, -(width - 1):]


class Mamba2LM(StackedCacheMixin):
    #: recurrent state is O(1) in sequence length — no serving context limit
    unbounded_context = True

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.d_inner = cfg.d_inner or 2 * cfg.d_model
        self.n_heads = self.d_inner // cfg.ssm_head_dim
        self.conv_dim = self.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        self.d_in_proj = 2 * self.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + self.n_heads

    # ------------------------------------------------------------------ init
    def _layer_init(self, key: jax.Array, ccfg: CascadeConfig) -> dict:
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        h = self.n_heads
        return {
            "ln": L.norm_init(cfg.d_model, cfg.norm_type),
            "in_proj": cascade.linear_init(k1, cfg.d_model, self.d_in_proj, ccfg),
            "conv_w": jax.random.normal(k2, (cfg.conv_width, self.conv_dim), jnp.float32) * 0.1,
            "conv_b": jnp.zeros((self.conv_dim,), jnp.float32),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
            "dt_bias": jnp.zeros((h,), jnp.float32),
            "D": jnp.ones((h,), jnp.float32),
            "gnorm": L.norm_init(self.d_inner),
            "out_proj": cascade.linear_init(k3, self.d_inner, cfg.d_model, ccfg),
        }

    def init_params(self, key: jax.Array, ccfg: CascadeConfig) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.n_layers + 2)
        params = {
            "layers": jax.vmap(lambda k: self._layer_init(k, ccfg))(keys[: cfg.n_layers]),
            "final_norm": L.norm_init(cfg.d_model, cfg.norm_type),
            "embed": L.embed_init(keys[-2], cfg.vocab, cfg.d_model, dtype=ccfg.compute_dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = cascade.linear_init(keys[-1], cfg.d_model, cfg.vocab, ccfg)
        return params

    # --------------------------------------------------------------- mixer
    def _split_proj(self, zxbcdt):
        di, g, n, h = self.d_inner, self.cfg.ssm_groups, self.cfg.ssm_state, self.n_heads
        z = zxbcdt[..., :di]
        xbc = zxbcdt[..., di: di + self.conv_dim]
        dt_raw = zxbcdt[..., di + self.conv_dim:]
        return z, xbc, dt_raw

    def _mixer(self, lp, u, ccfg, cache=None, mode="full", n_valid=None,
               collect: bool = False):
        cfg = self.cfg
        b, s, _ = u.shape
        di, g, n, h = self.d_inner, cfg.ssm_groups, cfg.ssm_state, self.n_heads
        p = cfg.ssm_head_dim
        zxbcdt = cascade.linear_apply(lp["in_proj"], u, ccfg)
        z, xbc, dt_raw = self._split_proj(zxbcdt)

        conv_full = None
        if mode == "decode":
            xbc_c, new_conv = _conv_decode(xbc, cache["conv"], lp["conv_w"], lp["conv_b"])
        elif mode == "extend":
            xbc_c, new_conv, conv_full = _conv_extend(xbc, cache["conv"], lp["conv_w"],
                                                      lp["conv_b"], n_valid)
        else:
            xbc_c = _causal_conv(xbc, lp["conv_w"], lp["conv_b"])
            new_conv = None  # prefill cache built below from the raw conv input
        xbc_c = jax.nn.silu(xbc_c)
        x = xbc_c[..., :di].reshape(b, -1, h, p)
        B = xbc_c[..., di: di + g * n].reshape(b, -1, g, n)
        C = xbc_c[..., di + g * n:].reshape(b, -1, g, n)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
        if mode == "extend" and n_valid is not None:
            # right-pad steps get dt=0: decay exp(0)=1 and zero input, so the
            # recurrent state passes through padding exactly unchanged
            dt = dt * (jnp.arange(s) < n_valid)[None, :, None]
        A = -jnp.exp(lp["A_log"])

        ckpt = None
        if mode == "decode":
            if ccfg.use_kernel:
                # fused serving: the single-token recurrence runs through the
                # Pallas SSD scan kernel (s = chunk = 1, slot states carried
                # in) — bit-exact with the jnp step in interpret mode. Extend
                # stays on the chunked dual form: its matmul-reassociated
                # arithmetic is a DIFFERENT (equally exact-to-spec) reduction
                # order, so routing it through the sequential kernel would
                # break prefill/decode cross-parity tests, not improve them.
                from repro.kernels import ops
                y, new_state = ops.ssd_decode(x, dt, A, B, C, lp["D"],
                                              cache["state"])
            else:
                y, new_state = ssd_decode_step(x, dt, A, B, C, lp["D"],
                                               cache["state"])
            new_cache = {"conv": new_conv, "state": new_state}
        elif mode == "extend" and collect:
            # chunk=1 SSD emits the state after EVERY token (states_prev with
            # unit chunks) — the per-position checkpoints a rejected draft
            # suffix rewinds onto; s is the small draft chunk, so the short
            # inter-chunk scan stays cheap
            y, final_state, st_prev = ssd_chunked(
                x, dt, A, B, C, lp["D"], 1, initial_state=cache["state"],
                return_chunk_states=True)
            new_cache = {"conv": new_conv, "state": final_state}
            ckpt = {"conv": conv_full,
                    "state": jnp.concatenate([st_prev, final_state[:, None]], axis=1)}
        elif mode == "extend":
            y, final_state = ssd_chunked(x, dt, A, B, C, lp["D"], cfg.ssm_chunk,
                                         initial_state=cache["state"])
            new_cache = {"conv": new_conv, "state": final_state}
        else:
            y, final_state = ssd_chunked(x, dt, A, B, C, lp["D"], cfg.ssm_chunk)
            new_cache = None
            if mode == "prefill":
                new_cache = {"conv": conv_prefill_state(xbc, cfg.conv_width),
                             "state": final_state}

        y = y.reshape(b, -1, di)
        y = L.norm_apply(lp["gnorm"], (y * jax.nn.silu(z.astype(jnp.float32))).astype(y.dtype))
        out = cascade.linear_apply(lp["out_proj"], y, ccfg)
        if collect:
            return out, new_cache, ckpt
        return out, new_cache

    def _block(self, lp, x, ccfg, cache, mode, n_valid=None, collect: bool = False):
        u = L.norm_apply(lp["ln"], x, self.cfg.norm_type)
        if collect:
            h, nc, ck = self._mixer(lp, u, ccfg, cache, mode, n_valid, collect=True)
            return constrain_residual(x + h), nc, ck
        h, nc = self._mixer(lp, u, ccfg, cache, mode, n_valid)
        return constrain_residual(x + h), nc

    # --------------------------------------------------------------- api
    def _head(self, params, x, ccfg):
        cfg = self.cfg
        x = L.norm_apply(params["final_norm"], x, cfg.norm_type)
        if cfg.tie_embeddings:
            logits = jnp.dot(x.astype(params["embed"]["table"].dtype), params["embed"]["table"].T,
                             preferred_element_type=jnp.float32)
        else:
            logits = cascade.linear_apply(params["lm_head"], x, ccfg)
        return logits.astype(jnp.float32)

    def forward(self, params, batch, ccfg, remat: bool = False,
                remat_policy: str = "dots"):
        x = L.embed_apply(params["embed"], batch["tokens"])

        def body(x, lp):
            y, _ = self._block(lp, x, ccfg, None, "full")
            return y, None

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(remat_policy))
        x, _ = lax.scan(body, x, params["layers"])
        return self._head(params, x, ccfg)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        h, p, n = self.n_heads, cfg.ssm_head_dim, cfg.ssm_state

        def one(_):
            return {
                "conv": jnp.zeros((batch, cfg.conv_width - 1, self.conv_dim), dtype),
                "state": jnp.zeros((batch, h, p, n), jnp.float32),  # recurrent acc stays f32
            }

        # positions are per-slot (B,) so a stacked grid holds streams of
        # different lengths (bookkeeping only — the recurrence is position-free)
        return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers)),
                "pos": jnp.zeros((batch,), jnp.int32)}

    def prefill(self, params, batch, ccfg, max_len: int | None = None):
        def body(x, lp):
            y, c = self._block(lp, x, ccfg, None, "prefill")
            return y, c

        x = L.embed_apply(params["embed"], batch["tokens"])
        b, s = batch["tokens"].shape
        x, caches = lax.scan(body, x, params["layers"])
        logits = self._head(params, x[:, -1:], ccfg)
        return logits, {"layers": caches, "pos": jnp.full((b,), s, jnp.int32)}

    def decode_step(self, params, batch, cache, ccfg):
        def body(x, scanned):
            lp, c = scanned
            y, nc = self._block(lp, x, ccfg, c, "decode")
            return y, nc

        x = L.embed_apply(params["embed"], batch["tokens"])
        b = batch["tokens"].shape[0]
        x, new_caches = lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = self._head(params, x, ccfg)
        return logits, {"layers": new_caches,
                        "pos": L.pos_rows(cache["pos"], b) + 1}

    def prefill_extend(self, params, batch, cache, ccfg, n_valid=None):
        """Append a (right-padded) token chunk to an existing recurrent
        cache: conv state carries across chunks and padded steps leave the
        SSD state untouched (dt=0 passthrough). Returns logits for the last
        valid token, (B, 1, V)."""
        x = L.embed_apply(params["embed"], batch["tokens"])
        b, s = batch["tokens"].shape
        nv = jnp.asarray(s if n_valid is None else n_valid, jnp.int32)

        def body(x, scanned):
            lp, c = scanned
            y, nc = self._block(lp, x, ccfg, c, "extend", n_valid=nv)
            return y, nc

        x, new_caches = lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = self._head(params, take_last_valid(x, nv), ccfg)
        return logits, {"layers": new_caches,
                        "pos": L.pos_rows(cache["pos"], b) + nv}

    # --------------------------------------------------- speculative decode
    def spec_verify(self, params, batch, cache, ccfg):
        """Score a (B, 1+K) draft chunk in ONE extend pass, checkpointing
        the recurrent state after EVERY chunk token (conv input windows +
        chunk-1 SSD states) — recurrences cannot be rewound in place, so a
        rejected suffix rolls back by selecting the checkpoint at the accept
        boundary."""
        x = L.embed_apply(params["embed"], batch["tokens"])
        b, s = batch["tokens"].shape

        def body(x, scanned):
            lp, c = scanned
            y, nc, ck = self._block(lp, x, ccfg, c, "extend", collect=True)
            return y, (nc, ck)

        x, (new_caches, cks) = lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = self._head(params, x, ccfg)
        pos0 = L.pos_rows(cache["pos"], b)
        return (logits, {"layers": new_caches, "pos": pos0 + s},
                {"layers": cks, "pos": pos0})

    def spec_rewind(self, cache, ckpt, keep):
        """Per-slot rewind to ``keep[b]`` committed chunk tokens: select the
        checkpointed {conv, ssd} state at the accept boundary, rewind pos."""
        w = self.cfg.conv_width
        ck = ckpt["layers"]        # conv: (L,B,w-1+s,dim); state: (L,B,s+1,h,p,n)
        conv = slice_rows_per_slot(ck["conv"], keep, 1, w - 1)
        state = slice_rows_per_slot(ck["state"], keep, 1, 1)[:, :, 0]
        return {"layers": {"conv": conv.astype(cache["layers"]["conv"].dtype),
                           "state": state},
                "pos": ckpt["pos"] + jnp.asarray(keep, jnp.int32)}

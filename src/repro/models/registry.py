"""arch id -> (config, model builder)."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

ARCH_IDS = [
    "mamba2_370m",
    "recurrentgemma_2b",
    "qwen25_32b",
    "phi4_mini_3_8b",
    "nemotron4_15b",
    "codeqwen15_7b",
    "deepseek_v2_236b",
    "olmoe_1b_7b",
    "musicgen_large",
    "qwen2_vl_2b",
]

# CLI ids use dashes matching the assignment table
ALIASES = {
    "mamba2-370m": "mamba2_370m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2.5-32b": "qwen25_32b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "nemotron-4-15b": "nemotron4_15b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "musicgen-large": "musicgen_large",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


#: one representative arch per serving family — the engine parity tests and
#: the serving benchmark/CI gate iterate over exactly these (smoke-sized)
FAMILY_SMOKE = {
    "transformer": "codeqwen1.5-7b",
    "moe": "deepseek-v2-236b",        # MLA latent cache + routed experts
    "griffin": "recurrentgemma-2b",   # ring-buffer KV + RG-LRU state
    "ssm": "mamba2-370m",             # conv + SSD state
}


def canonical(arch_id: str) -> str:
    return ALIASES.get(arch_id, arch_id)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch_id)}")
    return mod.smoke() if smoke else mod.CONFIG


def build_model(cfg: ArchConfig):
    if cfg.family == "ssm":
        from repro.models.ssm import Mamba2LM
        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        from repro.models.griffin import GriffinLM
        return GriffinLM(cfg)
    if cfg.family == "moe":
        from repro.models.moe import MoELM
        return MoELM(cfg)
    from repro.models.transformer import TransformerLM
    return TransformerLM(cfg)


def load(arch_id: str, smoke: bool = False):
    cfg = get_config(arch_id, smoke)
    return cfg, build_model(cfg)

"""AdamW optimizer (functional, optax-style triple but dependency-free).

Weight-decay masking skips norms/biases/scalars. Optimizer state shards like
the params (ZeRO-1-style sharding falls out of GSPMD when the param specs
are applied to the mirrored moment trees).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_frac: float = 0.1

    def init(self, params) -> AdamWState:
        zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
        return AdamWState(step=jnp.int32(0), mu=zeros(params), nu=zeros(params))

    def schedule(self, step):
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - self.warmup_steps) / max(self.decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (self.min_lr_frac + (1 - self.min_lr_frac) * cos)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)) + 1e-12)
        clip = jnp.minimum(1.0, self.grad_clip / gnorm)
        lr = self.schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * clip
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decay weights only (skip norms/biases/scalars)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v), {"gnorm": gnorm, "lr": lr}

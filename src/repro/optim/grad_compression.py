"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

At 512+ chips the DP gradient all-reduce is the dominant train-step
collective. Int8 compression with error feedback (Seide et al. 2014-style
residual carrying) cuts those bytes 4x vs f32 / 2x vs bf16 with no
asymptotic accuracy loss. Implemented as explicit (quantize -> psum ->
dequantize) so it can run inside a shard_map'ped step; the residual lives in
the train state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress(g: jax.Array, residual: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """g + residual -> (int8 codes, scale, new_residual)."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def allreduce_compressed(grads: Any, residuals: Any, axis_names) -> Tuple[Any, Any]:
    """psum int8-compressed grads over ``axis_names`` (inside shard_map).

    Returns (mean gradients f32, new residuals). The int8 codes are summed in
    int32 (no overflow below 2^23 participants), scales are max-combined —
    a conservative shared-scale scheme that keeps the wire format at 1 byte.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)) / 127.0 + 1e-12, axis_names)
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        new_r = gf - q * scale                           # error feedback
        summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_names)
        return (summed.astype(jnp.float32) * scale / n).astype(g.dtype), new_r

    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def init_residuals(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

"""Top-K weight sparsification (paper Section 10.13).

ZettaLith exploits 90-95% weight sparsity after Top-K sparsification to cut
*power* (zero weights still take a cycle). On TPU the analogous win is the
sparse-FLOPs accounting used in the roofline (the paper reports sparse
PFLOPS = 2x dense), plus the accuracy-preservation property that makes FP4
PTQ viable. We implement magnitude Top-K per output column, matching the
paper's per-column dataflow.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_sparsify(w: jax.Array, density: float, per_column: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Keep the top ``density`` fraction of weights by magnitude.

    Returns (sparsified weights, boolean keep-mask). ``per_column=True``
    ranks within each output column (axis 0 = contraction dim), which is the
    CASCADE column granularity.
    """
    if density >= 1.0:
        return w, jnp.ones_like(w, dtype=bool)
    k_dim = w.shape[0]
    keep = max(1, int(round(density * k_dim)))
    if per_column:
        mag = jnp.abs(w)
        # threshold = keep-th largest per column
        thresh = jnp.sort(mag, axis=0)[k_dim - keep]
        mask = mag >= thresh[None, :]
    else:
        flat = jnp.abs(w).reshape(-1)
        keep_n = max(1, int(round(density * flat.shape[0])))
        thresh = jnp.sort(flat)[flat.shape[0] - keep_n]
        mask = jnp.abs(w) >= thresh
    return jnp.where(mask, w, 0.0).astype(w.dtype), mask


def sparsity_stats(w: jax.Array) -> dict:
    total = w.size
    zeros = jnp.sum(w == 0)
    return {
        "total": total,
        "zeros": int(zeros),
        "sparsity": float(zeros / total),
        # Paper Table 5: zero weights toggle fewer nodes => activity factor drops
        # from 0.10 to 0.04 for zero weights; average alpha at sparsity s:
        "activity_factor": float(0.10 * (1 - zeros / total) + 0.04 * (zeros / total)),
    }

"""CREST — Cyclic REdundant Spare Testing (paper Sections 3.4, 20, 21).

ZettaLith's runtime fault tolerance: spare CASCADE columns recompute a
cyclically-rotating subset of live output columns with identical inputs and
copied weights; outputs are compared, >= ``threshold`` consecutive mismatches
confirm a fault (filtering cosmic-ray transients), and the faulty column is
remapped to a spare at a layer boundary with **zero throughput loss**
(paper: 16 spares per 8,208 columns ~= 0.2% overhead).

Software mapping (multi-pod TPU): the same dataflow detects silent data
corruption (SDC). A ``CrestState`` tracks per wrapped matmul:

* the cyclic test cursor (which live columns are being shadow-computed),
* consecutive-mismatch counters (cosmic-ray filtering, paper Section 20.2),
* the spare-slot assignment table (live column -> spare slot), applied every
  step so that confirmed-faulty columns are *permanently* served by spare
  recomputation — the paper's Figure 10f repair.

Total overhead is 2 * n_spares extra output columns per matmul (test copies
+ repair copies), independent of how many faults exist — matching the
paper's fixed-spare budget. Fault injection corrupts the live output of
chosen columns, mimicking defective PE columns; the spare path is computed
from the (pristine) weights, which the paper guarantees by running the test
copy in a known-good column with freshly copied weights.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class CrestConfig:
    n_spares: int = 16          # spare columns per array (paper: 16/8208)
    threshold: int = 3          # consecutive mismatches to confirm (Section 20.2)
    tol: float = 1e-4           # relative compare. The paper compares FP8 words
                                # exactly in identical PE hardware; in software the
                                # live (M,N) and spare (M,ns) matmuls may reduce in
                                # different orders, so we use a relative tolerance.


class CrestState(NamedTuple):
    cursor: jax.Array            # () int32 — first live column currently under test
    mismatch_count: jax.Array    # (n_cols,) int32 consecutive-mismatch counters
                                 # (per column: a column is re-tested every
                                 # n_cols/n_spares cycles; transients do not persist
                                 # across tests, so the counter still filters them)
    spare_assign: jax.Array      # (n_spares,) int32 col repaired by this slot, -1 = free
    confirmed_faults: jax.Array  # (n_cols,) bool
    n_repaired: jax.Array        # () int32


def crest_init(n_cols: int, cfg: CrestConfig) -> CrestState:
    return CrestState(
        cursor=jnp.int32(0),
        mismatch_count=jnp.zeros((n_cols,), jnp.int32),
        spare_assign=jnp.full((cfg.n_spares,), -1, jnp.int32),
        confirmed_faults=jnp.zeros((n_cols,), bool),
        n_repaired=jnp.int32(0),
    )


def crest_matmul(
    x: jax.Array,
    w: jax.Array,
    state: CrestState,
    cfg: CrestConfig,
    fault_mask: jax.Array | None = None,
) -> Tuple[jax.Array, CrestState]:
    """One CREST-protected matmul step. x: (M, K); w: (K, N)."""
    n = w.shape[1]
    ns = cfg.n_spares
    test_cols = (state.cursor + jnp.arange(ns, dtype=jnp.int32)) % n

    y_live = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if fault_mask is not None:
        corruption = jnp.where(fault_mask[None, :], 7.0 + y_live * 0.5, 0.0)
        y_live = y_live + corruption

    # --- cyclic testing: spare columns recompute the columns under test ---
    w_test = jnp.take(w, test_cols, axis=1)                       # (K, ns)
    y_spare = jnp.dot(x.astype(jnp.float32), w_test.astype(jnp.float32))
    y_tested = jnp.take(y_live, test_cols, axis=1)                # (M, ns)
    mismatch = jnp.any(
        jnp.abs(y_tested - y_spare) > cfg.tol * (1.0 + jnp.abs(y_spare)), axis=0)

    count = jnp.where(mismatch, state.mismatch_count[test_cols] + 1, 0)
    newly_confirmed = (count >= cfg.threshold) & ~state.confirmed_faults[test_cols]
    confirmed_faults = state.confirmed_faults.at[test_cols].set(
        state.confirmed_faults[test_cols] | newly_confirmed)

    # --- allocate free spare slots to newly confirmed columns ---
    def alloc(assign, i):
        free = assign == -1
        slot = jnp.argmax(free)
        can = newly_confirmed[i] & jnp.any(free)
        assign = jnp.where(can, assign.at[slot].set(test_cols[i]), assign)
        return assign, can

    spare_assign, allocated = lax.scan(alloc, state.spare_assign, jnp.arange(ns))

    # --- substitute spare recomputation for tested columns that just confirmed ---
    y = y_live.at[:, test_cols].set(
        jnp.where(newly_confirmed[None, :], y_spare, y_tested))

    # --- permanent repair path: recompute all spare-assigned columns ---
    assigned = spare_assign >= 0
    repair_cols = jnp.where(assigned, spare_assign, 0)
    w_rep = jnp.take(w, repair_cols, axis=1)                      # (K, ns)
    y_rep = jnp.dot(x.astype(jnp.float32), w_rep.astype(jnp.float32))
    scatter_idx = jnp.where(assigned, spare_assign, n)            # n = dropped
    slot_of_col = jnp.zeros((n,), jnp.int32).at[scatter_idx].set(
        jnp.arange(ns, dtype=jnp.int32), mode="drop")
    use_repair = jnp.zeros((n,), bool).at[scatter_idx].set(True, mode="drop")
    y = jnp.where(use_repair[None, :], jnp.take(y_rep, slot_of_col, axis=1), y)

    new_state = CrestState(
        cursor=(state.cursor + ns) % n,
        mismatch_count=state.mismatch_count.at[test_cols].set(
            jnp.where(newly_confirmed, 0, count)),
        spare_assign=spare_assign,
        confirmed_faults=confirmed_faults,
        n_repaired=state.n_repaired + jnp.sum(allocated.astype(jnp.int32)),
    )
    return y.astype(x.dtype), new_state


def inject_column_faults(key: jax.Array, n_cols: int, n_faults: int) -> jax.Array:
    """Boolean (n_cols,) mask with ``n_faults`` defective columns."""
    idx = jax.random.choice(key, n_cols, shape=(n_faults,), replace=False)
    return jnp.zeros((n_cols,), bool).at[idx].set(True)


def coverage_stats(state: CrestState, fault_mask: jax.Array) -> dict:
    detected = jnp.sum(state.confirmed_faults & fault_mask)
    false_pos = jnp.sum(state.confirmed_faults & ~fault_mask)
    return {
        "injected": int(jnp.sum(fault_mask)),
        "detected": int(detected),
        "false_positives": int(false_pos),
        "repaired": int(state.n_repaired),
    }

"""FP4/FP8 quantization core for the ZettaLith CASCADE reproduction.

Implements, faithfully to the paper (Sections 2.2, 10.1, 10.4, 10.6):

* FP4 E2M1 weight/activation codec (values +/-{0, .5, 1, 1.5, 2, 3, 4, 6}).
* FP5 E3M1 truncated products: the paper's multiplier truncates the 2-bit
  product mantissa 10.01b -> 10b ("the difference is minor"), i.e. mantissa
  round-toward-zero to one bit.
* FP8 E4M3 saturating, truncating accumulation (non-IEEE: no inf/nan path,
  saturates at +/-448, rounds toward zero) used to accumulate partial sums
  down a CASCADE column.
* Group-wise / per-column absmax post-training quantization (PTQ) of weight
  matrices into packed FP4 codes + scales.
* Quantization-aware-training (QAT) fake-quant with a straight-through
  estimator, as required by paper Section 4 for FP4 transformer deployment.

Everything here is pure jnp and serves as the numerical oracle for the Pallas
kernels in ``repro.kernels``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# --------------------------------------------------------------------------
# FP4 E2M1 codec
# --------------------------------------------------------------------------

#: Values of the 8 non-negative FP4 E2M1 codes (code = s<<3 | e<<1 | m).
FP4_VALUES = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32)
FP4_MAX = 6.0
#: Midpoints between adjacent positive values; ties round to even code
#: (matches RNE used by ml_dtypes' float4_e2m1fn cast).
_FP4_MIDPOINTS = (FP4_VALUES[1:] + FP4_VALUES[:-1]) / 2.0  # 7 midpoints

#: jax only exposes the float4_e2m1fn dtype from 0.4.39; older runtimes use
#: the pure-jnp grid rounding below (bit-identical, verified in tests).
HAS_NATIVE_FP4 = hasattr(jnp, "float4_e2m1fn")


def fp4_round(x: jax.Array) -> jax.Array:
    """Round f32 values onto the FP4 E2M1 value grid (RNE, saturating).

    Equivalent to ``x.astype(float4_e2m1fn).astype(float32)`` — used as the
    fallback when the runtime lacks the native dtype. All midpoints are
    exactly representable in f32, so the tie test is exact.
    """
    if HAS_NATIVE_FP4:
        return x.astype(jnp.float4_e2m1fn).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    mag = jnp.abs(xf)
    mid = jnp.asarray(_FP4_MIDPOINTS)
    lo = jnp.searchsorted(mid, mag, side="left")   # ties -> lower value index
    hi = jnp.searchsorted(mid, mag, side="right")  # ties -> upper value index
    idx = jnp.where(lo % 2 == 0, lo, hi)           # tie: pick even mantissa code
    mag4 = jnp.asarray(FP4_VALUES)[jnp.minimum(idx, 7)]
    out = jnp.where(jnp.signbit(xf), -mag4, mag4)
    return jnp.where(jnp.isnan(xf), xf, out)       # propagate NaN like the native cast


def fp4_encode(x: jax.Array) -> jax.Array:
    """Encode float -> FP4 E2M1 code (uint8 in 0..15), round-to-nearest-even.

    Rounds onto the FP4 value grid and maps the value back to its code via
    the magnitude table.
    """
    v = fp4_round(x)
    sign = (v < 0) | ((v == 0) & (jnp.signbit(x.astype(jnp.float32))))
    mag = jnp.abs(v)
    # searchsorted over the 8 exact magnitudes
    code = jnp.searchsorted(jnp.asarray(FP4_VALUES), mag, side="left").astype(jnp.uint8)
    return jnp.where(sign, code + jnp.uint8(8), code).astype(jnp.uint8)


def fp4_decode(code: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Decode FP4 E2M1 code (uint8 0..15) -> float, arithmetically (no gather).

    TPU-friendly decode used inside kernels as well:
      sign = bit3; e = bits2..1; m = bit0
      value = (-1)^sign * (e == 0 ? 0.5*m : (1 + 0.5*m) * 2^(e-1))
    """
    code = code.astype(jnp.int32)
    s = (code >> 3) & 1
    e = (code >> 1) & 3
    m = code & 1
    mf = m.astype(jnp.float32)
    ef = e.astype(jnp.float32)
    normal = (1.0 + 0.5 * mf) * jnp.exp2(ef - 1.0)
    sub = 0.5 * mf
    mag = jnp.where(e == 0, sub, normal)
    val = jnp.where(s == 1, -mag, mag)
    return val.astype(dtype)


def pack_fp4(codes: jax.Array, axis: int = 0) -> jax.Array:
    """Pack pairs of 4-bit codes along ``axis`` into uint8 (low nibble first)."""
    codes = jnp.moveaxis(codes, axis, 0)
    assert codes.shape[0] % 2 == 0, "packing axis must be even"
    lo = codes[0::2].astype(jnp.uint8)
    hi = codes[1::2].astype(jnp.uint8)
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return jnp.moveaxis(packed, 0, axis)


def unpack_fp4(packed: jax.Array, axis: int = 0) -> jax.Array:
    """Inverse of :func:`pack_fp4`."""
    packed = jnp.moveaxis(packed, axis, 0)
    lo = packed & jnp.uint8(0xF)
    hi = (packed >> 4) & jnp.uint8(0xF)
    inter = jnp.stack([lo, hi], axis=1)  # (P, 2, ...)
    out = inter.reshape((packed.shape[0] * 2,) + packed.shape[1:])
    return jnp.moveaxis(out, 0, axis)


# --------------------------------------------------------------------------
# Mantissa truncation primitives (FP5 product / FP8 accumulate)
# --------------------------------------------------------------------------


def truncate_mantissa_f32(x: jax.Array, mbits: int) -> jax.Array:
    """Truncate (round toward zero) an f32 mantissa to ``mbits`` bits.

    Works on sign-magnitude IEEE754 layout so it is correct for negatives.
    """
    xi = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF ^ ((1 << (23 - mbits)) - 1))
    return lax.bitcast_convert_type(xi & mask, jnp.float32)


def fp5_e3m1_product(a: jax.Array, b: jax.Array) -> jax.Array:
    """Paper Section 10.6 multiplier: FP4*FP4 with FP5 E3M1 truncated result.

    All 256 FP4xFP4 products have mantissas 1.0, 1.1 or 10.01 (binary); the
    multiplier truncates 10.01 -> 10 and renormalizes, i.e. a 1-bit mantissa
    truncation. With bias 2 the E3M1 exponent range [2^-2, 1.5*2^5] covers
    every product magnitude (0.25 .. 36) so no saturation path is needed
    (verified exhaustively in tests).
    """
    p = a.astype(jnp.float32) * b.astype(jnp.float32)
    return truncate_mantissa_f32(p, 1)


FP8_E4M3_MAX = 448.0
_FP8_MIN_NORMAL = 2.0 ** -6
_FP8_SUB_STEP = 2.0 ** -9


def fp8_e4m3_truncate(x: jax.Array) -> jax.Array:
    """Paper Sections 10.4/10.6 accumulator numerics: FP8 E4M3, saturating,
    truncating (round toward zero), non-IEEE (no inf/nan propagation).
    """
    x = x.astype(jnp.float32)
    sat = jnp.clip(x, -FP8_E4M3_MAX, FP8_E4M3_MAX)
    normal = truncate_mantissa_f32(sat, 3)
    sub = jnp.trunc(sat / _FP8_SUB_STEP) * _FP8_SUB_STEP
    return jnp.where(jnp.abs(sat) < _FP8_MIN_NORMAL, sub, normal)


def cascade_column_accumulate(products: jax.Array, init: jax.Array | None = None) -> jax.Array:
    """Sequentially accumulate FP5 products down a CASCADE column in FP8.

    ``products``: (..., K) FP5-truncated products in f32 carrier.
    Returns (...,) FP8-valued column sums. ``init`` models the bias preloaded
    into the output-sum HILT (paper Section 13.1).
    """
    k = products.shape[-1]
    acc0 = jnp.zeros(products.shape[:-1], jnp.float32) if init is None else init.astype(jnp.float32)

    def body(i, acc):
        return fp8_e4m3_truncate(acc + products[..., i])

    return lax.fori_loop(0, k, body, acc0)


def cascade_matmul_exact(x4: jax.Array, w4: jax.Array, bias: jax.Array | None = None) -> jax.Array:
    """Bit-accurate CASCADE matmul oracle.

    x4: (..., K) FP4-valued activations (f32 carrier, already FP4-rounded)
    w4: (K, N) FP4-valued weights
    Computes FP5-truncated products and FP8 sequential column accumulation —
    exactly the dataflow of paper Table 6. O(K*N) memory; test-scale only.
    """
    prods = fp5_e3m1_product(x4[..., :, None], w4[None, ...] if w4.ndim == 2 else w4)
    # prods: (..., K, N) -> accumulate over K sequentially per column
    prods = jnp.moveaxis(prods, -2, -1)  # (..., N, K)
    init = None
    if bias is not None:
        init = jnp.broadcast_to(fp8_e4m3_truncate(bias), prods.shape[:-1])
    return cascade_column_accumulate(prods, init)


# --------------------------------------------------------------------------
# PTQ: absmax group quantization of weight matrices
# --------------------------------------------------------------------------


def quantize_weight(
    w: jax.Array, group_size: int = 0
) -> Tuple[jax.Array, jax.Array]:
    """Quantize a (K, N) weight matrix to packed FP4 codes + scales.

    group_size: contraction-dim group for scales; 0 => one scale per output
    column (a single group spanning all of K). Scales are chosen so the group
    absmax maps to FP4_MAX (=6.0).

    Odd K (pad-to-pack): packing needs an even contraction dim, so an odd-K
    matrix is padded with one all-zero row first (zeros encode to code 0 and
    decode to exactly 0, so the pad contributes nothing to any product).
    Consumers pad the activations with a matching zero column — see
    ``kernels.ops.cascade_matmul`` / ``kernels.ref.cascade_matmul_ref``.
    Only supported with per-column scales (group_size=0): the pad row joins
    the single group without changing its absmax.

    Returns:
      packed: (ceil(K/2), N) uint8, two K-adjacent codes per byte (low
              nibble = even row)
      scales: (G, N) f32 with G = K//group_size (>= 1)
    """
    k, n = w.shape
    if k % 2:
        assert group_size == 0, "odd K needs per-column scales (group_size=0)"
        w = jnp.concatenate([w.astype(jnp.float32),
                             jnp.zeros((1, n), jnp.float32)], axis=0)
        k += 1
    g = group_size if group_size > 0 else k
    assert k % g == 0, f"K={k} not divisible by group_size={g}"
    wg = w.reshape(k // g, g, n).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(wg), axis=1)  # (G, N)
    scales = jnp.where(absmax > 0, absmax / FP4_MAX, 1.0)
    q = wg / scales[:, None, :]
    codes = fp4_encode(q).reshape(k, n)
    return pack_fp4(codes, axis=0), scales


def dequantize_weight(packed: jax.Array, scales: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of :func:`quantize_weight` -> (K, N) dense weights.

    For an odd-K original the returned matrix keeps the zero pad row
    (K+1 rows) — the codes alone cannot tell padded from real zeros."""
    codes = unpack_fp4(packed, axis=0)
    k, n = codes.shape
    g = k // scales.shape[0]
    vals = fp4_decode(codes, jnp.float32).reshape(k // g, g, n)
    return (vals * scales[:, None, :]).reshape(k, n).astype(dtype)


# --------------------------------------------------------------------------
# QAT fake-quant (straight-through estimator) — paper Section 4
# --------------------------------------------------------------------------


def fake_quant_fp4(w: jax.Array, group_size: int = 0) -> jax.Array:
    """Differentiable FP4 fake-quant: forward = quantize->dequantize,
    backward = identity (STE). Used for QAT so trained weights survive FP4
    serving (paper Section 4: 'effectively trained in FP4 using QAT')."""
    orig_shape = w.shape
    w2 = w.reshape(-1, orig_shape[-1]) if w.ndim != 2 else w
    k = w2.shape[0]
    g = group_size if (group_size > 0 and k % group_size == 0) else k

    def qdq(w2):
        wg = w2.reshape(k // g, g, -1).astype(jnp.float32)
        absmax = jnp.max(jnp.abs(wg), axis=1)
        scales = jnp.where(absmax > 0, absmax / FP4_MAX, 1.0)
        q = wg / scales[:, None, :]
        v = fp4_round(q)
        return (v * scales[:, None, :]).reshape(w2.shape)

    out = w2 + lax.stop_gradient(qdq(w2) - w2.astype(jnp.float32)).astype(w2.dtype)
    return out.reshape(orig_shape)


def fake_quant_fp8_e4m3(x: jax.Array) -> jax.Array:
    """FP8 fake-quant with STE (used for KV-cache QAT experiments)."""
    q = x.astype(jnp.float8_e4m3fn).astype(x.dtype)
    return x + lax.stop_gradient(q - x)

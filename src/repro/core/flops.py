"""Analytic parameter & FLOP accounting per architecture config.

Used by:
* benchmarks/table9_10.py — reproduces the paper's Llama-3.1-405B inference
  FLOPs/weight-loading balance analysis,
* benchmarks/roofline.py — MODEL_FLOPS = 6·N·D (dense train) or
  6·N_active·D (MoE), plus attention terms, compared against the
  loop-corrected HLO dot FLOPs to expose remat/redundancy waste.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig


def param_count(cfg: ArchConfig) -> dict:
    """Analytic parameter counts by component; 'total' and 'active'
    (= dense-equivalent params touched per token, for MoE)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.resolved_head_dim
    h, hk = cfg.n_heads, cfg.n_kv_heads
    counts = {"embed": 0 if cfg.input_embeds else V * d}
    head_width = V * max(1, cfg.n_codebooks)
    counts["lm_head"] = 0 if (cfg.tie_embeddings and not cfg.input_embeds) else d * head_width

    per_layer_attn = 0
    per_layer_mixer = 0
    if cfg.family == "ssm":
        di = cfg.d_inner or 2 * d
        nheads = di // cfg.ssm_head_dim
        conv_dim = di + 2 * cfg.ssm_groups * cfg.ssm_state
        d_in_proj = 2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + nheads
        per_layer_mixer = d * d_in_proj + cfg.conv_width * conv_dim + 3 * nheads + di * d
        counts["mixer"] = L * per_layer_mixer
        counts["mlp"] = 0
    elif cfg.family == "hybrid":
        lru = cfg.lru_width or d
        r_mix = d * lru * 2 + cfg.conv_width * lru + 2 * lru * lru + lru + lru * d
        a_mix = d * (h + 2 * hk) * hd + h * hd * d
        pat = cfg.block_pattern or ("R", "R", "A")
        n_groups = L // len(pat)
        n_r = n_groups * pat.count("R") + (L - n_groups * len(pat))
        n_a = n_groups * pat.count("A")
        counts["mixer"] = n_r * r_mix + n_a * a_mix
        glu = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
        counts["mlp"] = L * glu * d * cfg.d_ff if cfg.mlp_kind in ("swiglu", "geglu") \
            else L * 2 * d * cfg.d_ff
    elif cfg.family == "moe":
        if cfg.kv_lora:  # MLA
            qk = cfg.qk_nope_dim + cfg.qk_rope_dim
            per_layer_attn = (d * cfg.q_lora + cfg.q_lora * h * qk
                              + d * (cfg.kv_lora + cfg.qk_rope_dim)
                              + cfg.kv_lora * h * (cfg.qk_nope_dim + cfg.v_head_dim)
                              + h * cfg.v_head_dim * d)
        else:
            per_layer_attn = d * (h + 2 * hk) * hd + h * hd * d
        counts["attn"] = L * per_layer_attn
        expert = 3 * d * cfg.moe_d_ff
        n_moe_layers = L - cfg.first_dense_layers
        counts["experts"] = n_moe_layers * cfg.n_experts * expert
        counts["shared_experts"] = n_moe_layers * cfg.n_shared_experts * expert
        counts["router"] = n_moe_layers * d * cfg.n_experts
        counts["mlp"] = cfg.first_dense_layers * 3 * d * (cfg.dense_d_ff or cfg.d_ff)
    else:  # dense transformer families (incl. audio/vlm backbones)
        per_layer_attn = d * (h + 2 * hk) * hd + h * hd * d
        counts["attn"] = L * per_layer_attn
        glu = cfg.mlp_kind in ("swiglu", "geglu")
        counts["mlp"] = L * (3 if glu else 2) * d * cfg.d_ff

    total = sum(counts.values())
    active = total
    if cfg.family == "moe":
        n_moe_layers = L - cfg.first_dense_layers
        active = (total - counts["experts"]
                  + n_moe_layers * cfg.moe_top_k * 3 * d * cfg.moe_d_ff)
    counts["total"] = total
    counts["active"] = active
    return counts


def step_flops(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Analytic FLOPs for the cell's step (whole program, all devices).

    train:   6 * N_active * tokens  (fwd 2x + bwd 4x)  + attention terms
    prefill: 2 * N_active * tokens                     + attention terms
    decode:  2 * N_active * batch (one token each)     + cache attention
    Attention term (causal): 2 * 2 * h*hd * S^2/2 per layer per sequence =
    fwd QK^T + PV; trained adds the 2x backward factor.
    """
    pc = param_count(cfg)
    n_active = pc["active"] - pc.get("embed", 0)  # lookups are not matmul FLOPs
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h = cfg.n_heads

    if shape.kind == "train":
        tokens = b * s
        core = 6 * n_active * tokens
        attn = 0
        if cfg.family not in ("ssm",):
            w = cfg.window if cfg.window else s
            eff = min(w, s)
            # per layer: QK^T + PV = 2 * 2 * s * eff/2 * (h*hd), x3 for bwd
            n_attn = _n_attn_layers(cfg)
            attn = 3 * n_attn * b * (2 * 2 * s * (eff / 2) * h * hd)
        return {"core": core, "attn": attn, "total": core + attn, "tokens": tokens}

    if shape.kind == "prefill":
        tokens = b * s
        core = 2 * n_active * tokens
        attn = 0
        if cfg.family not in ("ssm",):
            w = cfg.window if cfg.window else s
            eff = min(w, s)
            n_attn = _n_attn_layers(cfg)
            attn = n_attn * b * (2 * 2 * s * (eff / 2) * h * hd)
        return {"core": core, "attn": attn, "total": core + attn, "tokens": tokens}

    # decode: 1 token per sequence against a cache of s
    tokens = b
    core = 2 * n_active * tokens
    attn = 0
    if cfg.family == "moe" and cfg.kv_lora:
        # MLA absorbed decode: scores+ctx over latent, per layer:
        lat = cfg.kv_lora + cfg.qk_rope_dim
        attn = cfg.n_layers * b * (2 * h * s * lat * 2
                                   + 2 * h * (cfg.qk_nope_dim * cfg.kv_lora) * 2)
    elif cfg.family == "ssm":
        attn = 0  # state update counted in core projections approx
    else:
        w = cfg.window if cfg.window else s
        eff = min(w, s)
        n_attn = _n_attn_layers(cfg)
        attn = n_attn * b * (2 * 2 * eff * cfg.n_kv_heads * (h // cfg.n_kv_heads) * hd)
    return {"core": core, "attn": attn, "total": core + attn, "tokens": tokens}


def _n_attn_layers(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("R", "R", "A")
        return (cfg.n_layers // len(pat)) * pat.count("A")
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers


def weight_bytes(cfg: ArchConfig, fp4: bool = True) -> int:
    """HBM bytes of resident weights (FP4 packed: 0.5 B/param + scales ~
    1/column; bf16 otherwise) — the quantity the paper's Table 10 streams."""
    pc = param_count(cfg)
    per = 0.5 if fp4 else 2.0
    return int(pc["total"] * per)

"""CascadeLinear — the paper's contribution as a composable JAX module.

A linear layer whose weights can live in three formats:

* ``train``     — bf16 dense weights; forward optionally applies FP4
                  fake-quant (QAT, paper Section 4).
* ``serve_fp4`` — packed FP4 codes + per-(group, column) scales; forward
                  dequantizes on the fly (XLA path) or calls the Pallas
                  kernel (TPU path). This is the paper-faithful serving
                  format: 4 bits/weight in HBM.
* ``bf16``      — plain dense baseline (the "GPU rack" reference point).

Distribution follows the CASCADE principle: the **output-column dimension is
the unit of parallelism** (PartitionSpec puts the last weight dim on the
``model`` mesh axis) so partial sums never cross chips — see
``repro.distributed.sharding`` for the policy table and the Megatron-style
baseline it is compared against.

All functions are functional (params are plain pytrees) so they compose with
pjit / scan / remat without framework baggage.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import quant


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Behavior of every CascadeLinear in a model."""
    mode: str = "train"            # train | serve_fp4 | bf16
    qat: bool = False              # fake-quant weights during training
    group_size: int = 0            # 0 => per-output-column scales
    use_kernel: bool = False       # Pallas kernel (TPU) vs XLA dequant-matmul
    precision_sim: bool = False    # bit-accurate FP8-accum path (tests only)
    compute_dtype: Any = jnp.bfloat16
    kv_dtype: Any = None           # KV/state cache dtype; None = follow
                                   # compute_dtype (fp8 = half the decode
                                   # memory term; industry-standard)

    @property
    def resolved_kv_dtype(self):
        """Storage dtype for KV/state caches (stacked slot grids included)."""
        return self.kv_dtype if self.kv_dtype is not None else self.compute_dtype


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def linear_init(key: jax.Array, d_in: int, d_out: int, cfg: CascadeConfig,
                use_bias: bool = False, scale: Optional[float] = None) -> dict:
    """Create params for one linear layer in the configured format."""
    scale = scale if scale is not None else 1.0 / (d_in ** 0.5)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return linear_from_dense(w, cfg, bias=jnp.zeros((d_out,), jnp.float32) if use_bias else None)


def linear_from_dense(w: jax.Array, cfg: CascadeConfig, bias: Optional[jax.Array] = None) -> dict:
    """Convert a dense (d_in, d_out) weight into the configured param format."""
    if cfg.mode == "serve_fp4":
        packed, scales = quant.quantize_weight(w, cfg.group_size)
        p = {"codes": packed, "scale": scales}
    else:
        p = {"w": w.astype(cfg.compute_dtype)}
    if bias is not None:
        p["b"] = bias.astype(jnp.float32)
    return p


def linear_abstract(d_in: int, d_out: int, cfg: CascadeConfig, use_bias: bool = False) -> dict:
    """ShapeDtypeStruct tree matching linear_init (for eval_shape-free spec building)."""
    if cfg.mode == "serve_fp4":
        g = (d_in // cfg.group_size) if cfg.group_size > 0 else 1
        p = {
            "codes": jax.ShapeDtypeStruct((d_in // 2, d_out), jnp.uint8),
            "scale": jax.ShapeDtypeStruct((g, d_out), jnp.float32),
        }
    else:
        p = {"w": jax.ShapeDtypeStruct((d_in, d_out), cfg.compute_dtype)}
    if use_bias:
        p["b"] = jax.ShapeDtypeStruct((d_out,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def linear_apply(params: dict, x: jax.Array, cfg: CascadeConfig) -> jax.Array:
    """y = x @ W (+ b) under the configured format/precision."""
    from repro.distributed.sharding import constrain_matmul_input
    x = constrain_matmul_input(x)
    b = params.get("b")
    if cfg.mode == "serve_fp4":
        if cfg.precision_sim:
            # Bit-accurate CASCADE: FP4 activations, FP5 products, FP8 column sums.
            w = quant.dequantize_weight(params["codes"], params["scale"], jnp.float32)
            xs = jnp.max(jnp.abs(x)) / quant.FP4_MAX
            x4 = quant.fp4_decode(quant.fp4_encode(x / xs))
            # scales factored out of the FP8 accumulation like the paper's
            # column-end scaling; weights re-normalized to FP4 grid per column.
            ws = jnp.max(jnp.abs(w), axis=0, keepdims=True) / quant.FP4_MAX
            ws = jnp.where(ws > 0, ws, 1.0)
            w4 = quant.fp4_decode(quant.fp4_encode(w / ws))
            out = quant.cascade_matmul_exact(x4, w4)
            out = out * (xs * ws)
            if b is not None:
                out = out + b
            return out.astype(cfg.compute_dtype)
        if cfg.use_kernel:
            from repro.kernels import ops  # lazy: keeps dryrun import-light
            out = ops.cascade_matmul(x, params["codes"], params["scale"], b,
                                     out_dtype=cfg.compute_dtype)
            return out
        w = quant.dequantize_weight(params["codes"], params["scale"], cfg.compute_dtype)
        out = jnp.dot(x.astype(cfg.compute_dtype), w,
                      preferred_element_type=jnp.float32)
        if b is not None:
            out = out + b
        return out.astype(cfg.compute_dtype)

    w = params["w"]
    if cfg.qat and cfg.mode == "train":
        w = quant.fake_quant_fp4(w, cfg.group_size)
    out = jnp.dot(x.astype(cfg.compute_dtype), w.astype(cfg.compute_dtype),
                  preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b
    return out.astype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# batched expert weights (MoE): leading E dim, FP4 per expert
# ---------------------------------------------------------------------------

def expert_linear_init(key: jax.Array, n_experts: int, d_in: int, d_out: int,
                       cfg: CascadeConfig) -> dict:
    keys = jax.random.split(key, n_experts)
    scale = 1.0 / (d_in ** 0.5)

    def one(k):
        w = jax.random.normal(k, (d_in, d_out), jnp.float32) * scale
        return linear_from_dense(w, cfg)

    return jax.vmap(one)(keys)


def expert_linear_apply(params: dict, x: jax.Array, cfg: CascadeConfig) -> jax.Array:
    """x: (E, C, d_in) -> (E, C, d_out); expert e uses its own weight."""
    from repro.distributed.sharding import constrain_replicated
    # CASCADE discipline mirrors linear_apply: the contraction input is
    # replicated (activation broadcast) so column-sharded expert weights
    # never emit a partial-sum all-reduce (no-op without a cascade policy)
    x = constrain_replicated(x)
    if cfg.mode == "serve_fp4":
        w = jax.vmap(lambda c, s: quant.dequantize_weight(c, s, cfg.compute_dtype))(
            params["codes"], params["scale"])
    else:
        w = params["w"]
        if cfg.qat and cfg.mode == "train":
            w = jax.vmap(lambda wi: quant.fake_quant_fp4(wi, cfg.group_size))(w)
    out = jnp.einsum("ecd,edf->ecf", x.astype(cfg.compute_dtype), w.astype(cfg.compute_dtype),
                     preferred_element_type=jnp.float32)
    return out.astype(cfg.compute_dtype)


def linear_weight(params: dict, cfg: CascadeConfig) -> jax.Array:
    """Dense (d_in, d_out) weight view of a CascadeLinear (used by MLA's
    weight-absorption decode path which needs the raw matrix)."""
    if cfg.mode == "serve_fp4":
        return quant.dequantize_weight(params["codes"], params["scale"], cfg.compute_dtype)
    return params["w"].astype(cfg.compute_dtype)


def tree_to_serve_fp4(params, cfg: CascadeConfig):
    """Convert a whole trained param tree (bf16/f32 dense) into the FP4
    serving format: every {"w"[, "b"]} linear dict becomes
    {"codes", "scale"[, "b"]}. Handles stacked layers (L, K, N) and stacked
    experts (L, E, K, N) by vmapping the quantizer over leading dims.
    Embeddings, norms, convs and routers stay dense."""
    import functools

    def conv(d):
        if isinstance(d, dict) and "w" in d and hasattr(d["w"], "ndim"):
            w = d["w"]
            qfn = functools.partial(quant.quantize_weight, group_size=cfg.group_size)
            for _ in range(w.ndim - 2):
                qfn = jax.vmap(qfn)
            codes, scale = qfn(w.astype(jnp.float32))
            out = {"codes": codes, "scale": scale}
            if "b" in d:
                out["b"] = d["b"]
            return out
        if isinstance(d, dict):
            return {k: conv(v) for k, v in d.items()}
        if isinstance(d, list):
            return [conv(v) for v in d]
        return d

    return conv(params)


def num_weight_bytes(params) -> int:
    """HBM bytes of the weight payload (the quantity Table 10 balances):
    every array leaf of the param tree at its storage dtype, so a serve_fp4
    tree counts 1 byte per packed code pair plus its scales — the number the
    weight-streaming decode bound divides by."""
    return sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(params))

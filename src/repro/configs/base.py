"""Architecture + shape configuration schema.

One ``ArchConfig`` per assigned architecture (exact public configs) plus a
``smoke()`` reduction of the same family for CPU tests. Input shapes are the
four assigned LM shapes; ``input_specs`` builds ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, no device allocation) for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"     # swiglu | geglu | relu2 | gelu
    norm_type: str = "rmsnorm"
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    tie_embeddings: bool = False
    # --- ssm (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    d_inner: int = 0
    ssm_chunk: int = 128
    # --- hybrid (recurrentgemma / griffin) ---
    window: int = 0              # local-attention window
    lru_width: int = 0
    block_pattern: Tuple[str, ...] = ()   # e.g. ("R", "R", "A")
    # --- moe ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0            # per-expert ffn dim
    first_dense_layers: int = 0  # deepseek-v2: layer 0 is a dense MLP
    dense_d_ff: int = 0          # ffn dim of those dense layers
    moe_capacity_factor: float = 1.25
    moe_renorm: bool = True
    # --- mla (deepseek-v2) ---
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- modality stubs ---
    n_codebooks: int = 0         # musicgen: parallel codebook heads
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl (half-dim units)
    input_embeds: bool = False   # stub frontend supplies (B, S, d) embeddings
    # --- implementation knobs ---
    q_chunk: int = 1024          # chunked-attention query block for long prefill
    scan_layers: bool = True
    subquadratic: bool = False   # supports the long_500k shape

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic attention (see DESIGN.md section 4)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False
    return True


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    specs = {}
    if arch.input_embeds:
        specs["inputs_embeds"] = jax.ShapeDtypeStruct((b, s, arch.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if arch.mrope_sections:
        specs["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    if shape.kind == "train":
        if arch.n_codebooks:
            specs["labels"] = jax.ShapeDtypeStruct((b, s, arch.n_codebooks), jnp.int32)
        else:
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs

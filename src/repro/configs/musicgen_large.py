"""musicgen-large [audio] — decoder-only over EnCodec tokens (arXiv:2306.05284).
48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048, 4 codebooks.
EnCodec frontend is a STUB: input_specs() supplies precomputed frame
embeddings (sum of the 4 codebook embeddings); sinusoidal positions."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, mlp_kind="gelu", norm_type="layernorm",
    rope_fraction=0.0, n_codebooks=4, input_embeds=True,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=32, n_codebooks=2)

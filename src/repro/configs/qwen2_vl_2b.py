"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (arXiv:2409.12191).
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
ViT frontend is a STUB: input_specs() supplies precomputed patch embeddings
plus (t, h, w) position ids for M-RoPE (sections 16/24/24 half-dims)."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), input_embeds=True,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, mrope_sections=(2, 3, 3))

"""olmoe-1b-7b [moe] — 64 experts top-8 (arXiv:2409.02060).
16L d_model=2048 16H (MHA kv=16) per-expert d_ff=1024 vocab=50304."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab=50304,
    n_experts=64, n_shared_experts=0, moe_top_k=8, moe_d_ff=1024,
    moe_renorm=False,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        n_experts=8, moe_top_k=2, moe_d_ff=32, vocab=256)

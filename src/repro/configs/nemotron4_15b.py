"""nemotron-4-15b [dense] — squared-ReLU MLP, partial RoPE (arXiv:2402.16819).
32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256000, mlp_kind="relu2", norm_type="layernorm",
    rope_fraction=0.5,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256)

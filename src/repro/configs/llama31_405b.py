"""Llama 3.1 405B — the paper's own example model (Tables 8/9/10).
Used by the benchmark harness to reproduce the paper's FLOP and
weight-loading analysis; not an assigned dry-run architecture."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.1-405b", family="dense",
    n_layers=80, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=65536, vocab=128000, rope_theta=5e5,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=256)

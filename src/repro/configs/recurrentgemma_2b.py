"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2 (arXiv:2402.19427).
26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window=2048."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, mlp_kind="geglu",
    window=2048, lru_width=2560, block_pattern=("R", "R", "A"),
    conv_width=4, subquadratic=True,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, window=16, lru_width=64)

"""mamba2-370m [ssm] — SSD state-space duality (arXiv:2405.21060).
48L d_model=1024, attention-free, ssm_state=128, vocab=50280."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=32, n_kv_heads=32, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_groups=1,
    d_inner=2048, conv_width=4, tie_embeddings=True,
    subquadratic=True,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_inner=128, ssm_state=16, ssm_head_dim=32, vocab=256, ssm_chunk=8)

"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
(arXiv:2405.04434). 60L d_model=5120 128H, per-expert d_ff=1536, vocab=102400."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    n_experts=160, n_shared_experts=2, moe_top_k=6, moe_d_ff=1536,
    first_dense_layers=1, dense_d_ff=12288,
    kv_lora=512, q_lora=1536, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, n_experts=8, n_shared_experts=1, moe_top_k=2, moe_d_ff=32,
        first_dense_layers=1, dense_d_ff=128, vocab=256, moe_capacity_factor=2.0,
        kv_lora=32, q_lora=48, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)

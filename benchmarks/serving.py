"""Serving-path benchmark: batched vs slot-wise continuous-batching decode,
across every registry arch family.

Measures steady-state decode throughput of ``ServeEngine`` across batch
sizes, in both engine modes:

* ``slotwise`` — the legacy per-slot Python loop: one jitted ``decode_step``
  dispatch per resident request per token (weight streaming paid ``batch``
  times per engine step);
* ``batched``  — the stacked-cache grid: ONE donated, jitted ``decode_step``
  over all slots per engine step (weight streaming paid once — the paper's
  Table 9/10 batching balance). Every family runs it over its own state:
  full KV, MLA latents, ring buffers + recurrent {conv, h}, SSD state;
* ``spec`` (``--spec``) — speculative decode on the batched grid: prompt-
  lookup drafts + ONE verify pass per step, on a **repetitive-text
  workload** (the output head is zeroed so greedy argmax is a constant
  token — the acceptance CEILING: it isolates the engine's draft/verify/
  rewind mechanics from model quality, which for these random-init smoke
  nets would otherwise make acceptance an accident of the seed; the
  tests/test_spec.py parity suite guarantees correctness on arbitrary
  streams). Rows add ``accepted_per_step`` and ``speedup_vs_batched``.

  With ``--temperature T > 0`` the spec rows run **speculative sampling**
  instead. The zeroed head now gives a UNIFORM p over the vocab, so the
  acceptance ceiling is a different number than greedy's K: sampled
  acceptance commits a draft with probability p(draft) — the model's own
  probability mass on it — not an argmax indicator, so per real draft the
  accept probability is 1/V and the expected accepted/step ceiling is
  ``sum_{j=1..K} V^-j ~= 1/V`` (the distribution-exactness guarantee is
  exactly why: a drafter cannot be accepted more often than the model
  itself would emit its proposals). To keep that ceiling measurable and
  gateable the sampled spec rows (and their batched baseline) shrink the
  vocab to ``SPEC_SAMPLED_VOCAB``; ``--min-accept`` then gates against the
  analytic ceiling with CI-noise margin.

* ``fused`` (``--fused``) — the Pallas fused FP4 decode path: packed-FP4
  codes + scales held end-to-end, linears dispatched to the CASCADE matmul
  kernel (and single-token attention to the decode kernel), measured
  against a ``fp4`` jnp dequant-matmul baseline serving the SAME packed
  weights. Fused rows add ``weight_stream_bytes_per_device``,
  ``decode_bound_tokens_per_s`` (= max_batch / (weight_bytes / HBM_BW),
  the weight-streaming decode ceiling) and ``fraction_of_bound`` — the
  measured-vs-bound ratio ``benchmarks/report.py`` renders. On CPU the
  kernels run in interpret mode, so the ratio is a smoke number; the
  token-exactness contract is what tests/test_fused.py gates.

Emits one JSON row per (arch, mode, batch) into ``--out`` in the same row
style the roofline sweeps use (``arch``/``shape``/``status`` keys), so
``benchmarks/report.py`` renders it alongside the other tables.

``--min-speedup X`` turns the run into a REGRESSION GATE: exit non-zero if
batched throughput is below X times slot-wise for any covered arch/batch
(CI runs this at 1.5x and uploads the JSON as a workflow artifact);
``--min-accept Y`` gates spec rows at >= Y accepted draft tokens per
(slot, step) (CI runs this at 1.0).

* ``paged`` (``--paged``) — the page-pool + radix-prefix-cache engine
  (PR 9): decode throughput rows against the dense batched baseline (the
  block-table gather is the only difference, so the speedup column
  isolates paged-read overhead), plus ONE report-only ``prefix_probe``
  row per family measuring warm-vs-cold prefill TTFT: a cold request
  pays full prefill for its shared prefix, a warm request with the SAME
  prefix admits through resident pages and only prefills its unique
  suffix. Fields (``ttft_cold_s``/``ttft_warm_s``/``prefix_hit_rate``)
  are report-only here; the gated warm<cold check lives in
  ``benchmarks/traffic.py`` where the open-loop trace drives it.

* ``mesh`` (``--mesh DxM``, typically with ``--host-devices 8``) — the
  batched engine on a real ``NamedSharding`` mesh: params placed by
  ``--tp-policy`` (cascade column-parallel by default), stacked caches
  sharded on their slot axis over ``data``. Rows record the decode step's
  **partial-sum all-reduce count** (``hlo_analysis.partial_sum_allreduces``)
  and the run FAILS if a cascade-policy step contains any — the paper's
  zero-partial-sum claim as a bench gate. Virtual CPU devices share the
  same cores, so mesh rows measure placement overhead, not speedup; the
  interconnect claim is the HLO column.

Run: PYTHONPATH=src:. python -m benchmarks.serving \
        [--archs transformer moe griffin ssm] [--batches 2]
        [--min-speedup 1.5] [--spec] [--draft-len 4] [--min-accept 1.0]
        [--temperature 1.0]
        [--mesh 4x2 --host-devices 8 --tp-policy cascade]
        [--out results/bench_serving.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

#: per-family dimension overrides on top of the smoke config: large enough
#: that weight streaming (not dispatch overhead alone) dominates a decode
#: step, small enough for CPU CI
FAMILY_DIMS = {
    "transformer": dict(d_model=256, n_layers=4, d_ff=1024, vocab=2048,
                        n_heads=8, n_kv_heads=8),
    "moe": dict(d_model=256, n_layers=3, vocab=2048, moe_d_ff=512,
                dense_d_ff=1024, kv_lora=64, q_lora=96),
    "griffin": dict(d_model=256, n_layers=5, d_ff=768, vocab=2048,
                    lru_width=256, window=64),
    "ssm": dict(d_model=256, n_layers=4, d_inner=512, ssm_head_dim=64,
                vocab=2048),
}
PROMPT_LEN = 16
MEASURE_STEPS = 24
WARMUP_STEPS = 3
REPEATS = 3       # best-of-N throughput per mode: one noisy-neighbor burst
                  # on a shared CI runner must not fail the gate

#: HBM bandwidth the weight-streaming decode bound divides by (TPUv4-class,
#: matching the roofline sweeps). Deliberately DUPLICATED from
#: benchmarks/roofline.py instead of imported: importing roofline pulls in
#: repro.launch.dryrun, whose module-level host-device override would force
#: this process onto 512 virtual devices
HBM_BW = 819e9


def _force_constant_argmax(params: dict) -> dict:
    """Zero the output head (tied archs: the embedding table) so greedy
    argmax emits one constant token forever — a maximally repetitive
    stream, i.e. the spec-decode acceptance ceiling."""
    p = dict(params)
    key = "lm_head" if "lm_head" in p else "embed"
    p[key] = jax.tree.map(jnp.zeros_like, p[key])
    return p


#: cache length for the spec comparison: speculation commits up to
#: draft_len+1 tokens per slot per step, so bounded-context archs need a
#: longer grid to not context-retire mid-measurement; the batched baseline
#: that spec is compared against runs at the SAME length so the speedup
#: column is apples-to-apples (attention cost grows with the cache)
SPEC_MAX_LEN = 1024

#: vocab for the SAMPLED spec rows (and their batched baseline): the zeroed
#: head gives uniform p, so sampled acceptance is Bernoulli(1/V) per draft
#: and the analytic accepted/step ceiling is sum_{j=1..K} V^-j — at the
#: greedy rows' vocab (2048) that is ~0.0005, unmeasurable in a short CI
#: run; at 8 it is ~0.143, gateable with margin (see sampled_accept_ceiling)
SPEC_SAMPLED_VOCAB = 8


def sampled_accept_ceiling(vocab: int, draft_len: int) -> float:
    """E[accepted drafts / slot-step] for uniform p and i.i.d. real drafts:
    the leading-accept count of Bernoulli(1/V) trials, sum_{j=1..K} V^-j."""
    return sum(vocab ** -j for j in range(1, draft_len + 1))


def build_engine(family: str, mode: str, max_batch: int, draft_len: int = 4,
                 max_len: int = 128, mesh=None, tp_policy: str = "cascade",
                 temperature: float = 0.0, vocab: int = 0):
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.serve.engine import ServeConfig, ServeEngine

    arch = registry.FAMILY_SMOKE[family]
    dims = dict(FAMILY_DIMS[family])
    if vocab:
        dims["vocab"] = vocab
    cfg = dataclasses.replace(registry.get_config(arch, smoke=True), **dims)
    model = registry.build_model(cfg)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), ccfg)
    if mode == "spec":
        params = _force_constant_argmax(params)
    if mode in ("fp4", "fused"):
        # the FP4 serving format: packed codes + scales end-to-end; "fused"
        # routes them through the Pallas kernels, "fp4" is its jnp
        # dequant-matmul baseline (same weights, same numerics contract)
        from repro.core import cascade
        ccfg = CascadeConfig(mode="serve_fp4", compute_dtype=jnp.float32)
        params = cascade.tree_to_serve_fp4(params, ccfg)
    scfg = ServeConfig(max_batch=max_batch, max_len=max_len,
                       batched=(mode != "slotwise"), prefill_chunk=PROMPT_LEN,
                       draft_len=(draft_len if mode == "spec" else 0),
                       temperature=temperature, tp_policy=tp_policy,
                       fused=(mode == "fused"),
                       prefix_cache=(mode == "paged"))
    return cfg, ServeEngine(model, params, ccfg, scfg,
                            mesh=(mesh if mode == "mesh" else None))


def bench_mode(family: str, mode: str, max_batch: int, draft_len: int = 4,
               max_len: int = 128, mesh=None, tp_policy: str = "cascade",
               temperature: float = 0.0, vocab: int = 0) -> dict:
    from repro.serve.engine import Request

    cfg, eng = build_engine(family, mode, max_batch, draft_len, max_len,
                            mesh, tp_policy, temperature, vocab)
    rng = np.random.default_rng(0)
    pat = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    for i in range(max_batch):
        prompt = (np.tile(pat, PROMPT_LEN // 4) if mode == "spec"   # repetitive text
                  else rng.integers(0, cfg.vocab, PROMPT_LEN).astype(np.int32))
        eng.submit(Request(uid=i, prompt=prompt,
                           max_new_tokens=1_000_000))  # never retire during run
    for _ in range(1 + WARMUP_STEPS):       # admit-all step + jit warmup
        eng.step()
    assert all(s is not None for s in eng.slots)
    if mode == "spec":
        assert eng.spec, "spec bench must take the speculative path"
        want = "spec-sampled" if temperature > 0 else "spec-greedy"
        assert eng.effective_mode == want, eng.effective_mode
    if mode == "fused":
        # never report a silently-downgraded run as a kernel measurement
        assert eng.effective_mode.endswith("-fused"), (
            f"fused bench downgraded: {eng.effective_mode} "
            f"({'; '.join(eng.downgrades)})")
    if mode == "paged":
        assert eng.effective_mode.endswith("-paged"), (
            f"paged bench downgraded: {eng.effective_mode} "
            f"({'; '.join(eng.downgrades)})")
    eng.step_times.clear()                  # drop trace/compile steps from p50/p99
    best_dt, produced = float("inf"), 0
    for _ in range(REPEATS):                # best-of-N: robust to CPU bursts
        t0 = time.perf_counter()
        rep = 0
        for _ in range(MEASURE_STEPS):
            rep += eng.step()
        dt = time.perf_counter() - t0
        if dt < best_dt:
            best_dt, produced = dt, rep
    dt = best_dt
    m = eng.metrics()
    row = {
        "arch": cfg.name,
        "family": family,
        "shape": f"serve_decode_b{max_batch}",
        "mode": mode,
        "status": "ok",
        "max_batch": max_batch,
        "decode_tokens": produced,
        "wall_s": round(dt, 4),
        "tokens_per_s": round(produced / dt, 2),
        "step_ms_p50": round(m["step_time_p50_s"] * 1e3, 2),
        "step_ms_p99": round(m["step_time_p99_s"] * 1e3, 2),
    }
    if mode == "spec":
        row["draft_len"] = m["draft_len"]
        row["accepted_per_step"] = round(m["accepted_per_step"], 4)
    if mode in ("fp4", "fused"):
        row["weights"] = "fp4"
    if mode == "paged":
        row["page_size"] = m["page_size"]
        row["pages_in_use"] = m["pages_in_use"]
    if mode == "fused":
        # measured decode throughput vs the weight-streaming bound: decoding
        # one token per slot must stream every live weight byte once, so the
        # ceiling is max_batch / (weight_bytes / HBM_BW) tokens/s per device
        # (paper Table 10's balance). On CPU CI the ratio is a smoke number
        # (the bound assumes TPU HBM), but the FIELDS are the contract
        # report.py renders
        from repro.core.cascade import num_weight_bytes
        wb = int(num_weight_bytes(eng.params))
        bound = max_batch / (wb / HBM_BW)
        row["weight_stream_bytes_per_device"] = wb
        row["decode_bound_tokens_per_s"] = round(bound, 2)
        row["fraction_of_bound"] = round(row["tokens_per_s"] / bound, 6)
    if temperature > 0:
        row["temperature"] = temperature
        row["vocab"] = cfg.vocab
    if mode == "mesh":
        from benchmarks import hlo_analysis
        ar = hlo_analysis.partial_sum_allreduces(eng.decode_step_hlo())
        row["mesh"] = m["mesh"]
        row["tp_policy"] = tp_policy
        row["partial_sum_allreduces"] = ar["count"]
        row["partial_sum_allreduce_bytes"] = ar["bytes"]
    return row


def probe_prefix_ttft(family: str, prefix_len: int = 64,
                      suffix_len: int = 8) -> dict | None:
    """Report-only warm-vs-cold prefill probe for the prefix cache.

    Cold: a request whose shared prefix is NOT resident pays full prefill.
    Warm: a request with the SAME prefix admits through the radix tree's
    resident pages and only prefills its unique suffix. Both TTFTs are
    wall-clocked over a jit-warm engine (a throwaway request compiles every
    chunk shape first), best-of-``REPEATS`` with a FRESH shared prefix per
    cold repeat (a repeated cold prompt would hit the tree and stop being
    cold). Returns ``None`` for families the paged engine downgrades on
    (ring/recurrent state has no page-gather read path)."""
    import warnings

    from repro.serve.engine import Request

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        cfg, eng = build_engine(family, "paged", max_batch=2,
                                max_len=2 * (prefix_len + suffix_len + 8))
    if not eng.paged:
        return None
    rng = np.random.default_rng(0)
    uid = [0]

    def ttft(prompt: np.ndarray) -> float:
        uid[0] += 1
        req = Request(uid=uid[0], prompt=prompt, max_new_tokens=2)
        eng.submit(req)
        t0 = time.perf_counter()
        while not req.tokens_out:
            eng.step()
        dt = time.perf_counter() - t0
        while eng.busy():
            eng.step()
        return dt

    # jit warmup: same total length => every prefill chunk shape (full and
    # ragged tail) plus the decode step compile here, off the measurement
    ttft(rng.integers(0, cfg.vocab, prefix_len + suffix_len).astype(np.int32))
    cold, warm = float("inf"), float("inf")
    for _ in range(REPEATS):
        shared = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
        sfx = [rng.integers(0, cfg.vocab, suffix_len).astype(np.int32)
               for _ in range(2)]
        cold = min(cold, ttft(np.concatenate([shared, sfx[0]])))
        warm = min(warm, ttft(np.concatenate([shared, sfx[1]])))
    m = eng.metrics()
    return {
        "arch": cfg.name,
        "family": family,
        "shape": "prefix_probe",
        "mode": "paged",
        "status": "ok",
        "prefix_len": prefix_len,
        "suffix_len": suffix_len,
        "page_size": m["page_size"],
        "ttft_cold_s": round(cold, 6),
        "ttft_warm_s": round(warm, 6),
        "warm_speedup": round(cold / max(warm, 1e-9), 2),
        "prefix_hit_rate": round(m["prefix_hit_rate"], 4),
        "pages_in_use": m["pages_in_use"],
        "evictions": m["evictions"],
    }


def main():
    from repro.models import registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/bench_serving.json")
    ap.add_argument("--archs", nargs="*", default=sorted(registry.FAMILY_SMOKE),
                    choices=sorted(registry.FAMILY_SMOKE),
                    help="arch families to cover")
    ap.add_argument("--batches", type=int, nargs="*", default=[1, 2, 4, 8])
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail (exit 1) if batched/slotwise throughput falls "
                         "below this for any covered arch (0 = report only)")
    ap.add_argument("--spec", action="store_true",
                    help="also bench speculative decode (repetitive-text "
                         "acceptance-ceiling workload)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="drafted tokens per slot per step for --spec")
    ap.add_argument("--min-accept", type=float, default=0.0,
                    help="fail (exit 1) if the spec bench accepts fewer "
                         "drafted tokens per (slot, step) than this (with "
                         "--temperature > 0 gate against the analytic "
                         "sampled ceiling sum_{j<=K} V^-j, printed per row, "
                         "not against the greedy ceiling K)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="run the spec rows (and their batched baseline) "
                         "with sampled decoding — speculative SAMPLING at "
                         "this temperature on a shrunken vocab "
                         f"({SPEC_SAMPLED_VOCAB}) so the uniform-p "
                         "acceptance ceiling stays measurable")
    ap.add_argument("--fused", action="store_true",
                    help="also bench the Pallas fused FP4 decode path "
                         "against its jnp dequant-matmul baseline (same "
                         "packed weights); fused rows report the measured-"
                         "vs-weight-streaming-bound ratio")
    ap.add_argument("--fused-only", action="store_true",
                    help="bench ONLY the fused + fp4-baseline rows (no "
                         "slotwise/batched sweeps): the CI fused-decode leg "
                         "gates kernel dispatch, not batching speedups")
    ap.add_argument("--paged", action="store_true",
                    help="also bench the page-pool engine: paged decode "
                         "throughput vs the dense batched baseline, plus a "
                         "report-only warm-vs-cold prefix-cache TTFT probe "
                         "per family (the gated warm<cold check lives in "
                         "benchmarks/traffic.py)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="also bench the sharded engine on a (data, model) "
                         "host mesh, e.g. 4x2; cascade rows must show ZERO "
                         "partial-sum all-reduce or the run fails")
    ap.add_argument("--mesh-only", action="store_true",
                    help="bench ONLY the mesh mode (no slotwise/batched "
                         "sweeps): single-device modes measured on an "
                         "oversubscribed virtual-device host would pollute "
                         "the measured-vs-bound join, and the CI mesh leg "
                         "only needs the AR gate + mesh row")
    ap.add_argument("--spec-only", action="store_true",
                    help="bench ONLY the spec rows (plus their same-config "
                         "batched baseline, which is measured but not "
                         "emitted): skips the slotwise/batched sweeps so "
                         "the CI spec-sampling leg neither re-times modes "
                         "it does not gate nor emits greedy rows that "
                         "would collide with the bench-gate artifact's in "
                         "the report join")
    ap.add_argument("--tp-policy", default="cascade",
                    choices=["cascade", "megatron"])
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N virtual CPU devices before first jax use")
    args = ap.parse_args()

    if args.mesh_only and not args.mesh:
        ap.error("--mesh-only requires --mesh")
    if args.mesh_only and (args.spec or args.min_speedup > 0
                           or args.min_accept > 0):
        # never let a gate invocation exit green having skipped the gated
        # benches — the single-device modes simply don't run under mesh-only
        ap.error("--mesh-only skips the slotwise/batched/spec benches; it is "
                 "incompatible with --spec/--min-speedup/--min-accept")
    if args.spec_only and not args.spec:
        ap.error("--spec-only requires --spec")
    if args.spec_only and (args.mesh_only or args.min_speedup > 0):
        ap.error("--spec-only skips the slotwise/batched sweeps; it is "
                 "incompatible with --mesh-only/--min-speedup")
    if args.fused_only and not args.fused:
        ap.error("--fused-only requires --fused")
    if args.fused_only and (args.mesh_only or args.spec_only or args.spec
                            or args.min_speedup > 0 or args.min_accept > 0):
        ap.error("--fused-only skips every non-fused bench; it is "
                 "incompatible with --spec/--mesh-only/--spec-only/"
                 "--min-speedup/--min-accept")

    from repro.launch import mesh as meshlib
    if args.host_devices:
        meshlib.force_host_device_count(args.host_devices)
    mesh = meshlib.make_serving_mesh(args.mesh) if args.mesh else None

    rows, failures = [], []
    paged_ok = {}         # family -> probe row (None = downgraded, skip)
    for family in args.archs:
        for b in args.batches:
            bat = None
            if args.fused or args.fused_only:
                # jnp FP4 baseline first: same packed weights, same engine,
                # kernel dispatch is the ONLY difference — so the speedup
                # column isolates the kernel (on CPU CI, interpret-mode
                # overhead; on TPU, the fused win)
                fp4 = bench_mode(family, "fp4", b)
                fu = bench_mode(family, "fused", b)
                fu["speedup_vs_fp4_jnp"] = round(
                    fu["tokens_per_s"] / max(fp4["tokens_per_s"], 1e-9), 2)
                rows += [fp4, fu]
                print(f"{family:12s} b={b:2d}  "
                      f"fp4(jnp) {fp4['tokens_per_s']:9.1f} tok/s   "
                      f"fused {fu['tokens_per_s']:9.1f} tok/s   "
                      f"bound {fu['decode_bound_tokens_per_s']:11.1f} tok/s   "
                      f"measured/bound {fu['fraction_of_bound']:.2e}")
            if args.fused_only:
                continue
            if not args.mesh_only and not args.spec_only:
                slot = bench_mode(family, "slotwise", b)
                bat = bench_mode(family, "batched", b)
                speedup = bat["tokens_per_s"] / max(slot["tokens_per_s"], 1e-9)
                bat["speedup_vs_slotwise"] = slot["speedup_vs_slotwise"] = round(speedup, 2)
                rows += [slot, bat]
                print(f"{family:12s} b={b:2d}  "
                      f"slotwise {slot['tokens_per_s']:9.1f} tok/s   "
                      f"batched {bat['tokens_per_s']:9.1f} tok/s   "
                      f"speedup {speedup:5.2f}x")
                if args.min_speedup > 0 and speedup < args.min_speedup:
                    failures.append(f"{family} b={b}: {speedup:.2f}x "
                                    f"< {args.min_speedup:.2f}x")
            if args.paged and not args.mesh_only and not args.spec_only:
                if family not in paged_ok:
                    paged_ok[family] = probe_prefix_ttft(family)
                    if paged_ok[family] is not None:
                        pr = paged_ok[family]
                        rows.append(pr)
                        print(f"{'':12s}       prefix probe: cold "
                              f"{pr['ttft_cold_s'] * 1e3:7.2f} ms  warm "
                              f"{pr['ttft_warm_s'] * 1e3:7.2f} ms  "
                              f"({pr['warm_speedup']:.2f}x, hit rate "
                              f"{pr['prefix_hit_rate']:.2f})")
                    else:
                        print(f"{family:12s}       paged: downgraded "
                              "(no page-gather read path), skipped")
                if paged_ok[family] is not None:
                    pg = bench_mode(family, "paged", b)
                    if bat is not None:
                        pg["speedup_vs_batched"] = round(
                            pg["tokens_per_s"]
                            / max(bat["tokens_per_s"], 1e-9), 2)
                    rows.append(pg)
                    print(f"{'':12s}       paged    {pg['tokens_per_s']:9.1f} "
                          f"tok/s   pages {pg['pages_in_use']}")
            if args.spec and not args.mesh_only:
                # sampled spec runs on the shrunken vocab (see module
                # docstring); its baseline matches it exactly — same vocab,
                # same temperature — so the speedup column stays honest
                svocab = SPEC_SAMPLED_VOCAB if args.temperature > 0 else 0
                sp = bench_mode(family, "spec", b, args.draft_len,
                                max_len=SPEC_MAX_LEN,
                                temperature=args.temperature, vocab=svocab)
                # same-cache-size batched baseline: isolates the speculative
                # gain from the longer grid's attention cost
                bat_ref = bench_mode(family, "batched", b, max_len=SPEC_MAX_LEN,
                                     temperature=args.temperature, vocab=svocab)
                sp["speedup_vs_batched"] = round(
                    sp["tokens_per_s"] / max(bat_ref["tokens_per_s"], 1e-9), 2)
                rows.append(sp)
                extra = ""
                if args.temperature > 0:
                    ceil = sampled_accept_ceiling(SPEC_SAMPLED_VOCAB,
                                                  args.draft_len)
                    extra = (f"   [sampled T={args.temperature:g}, uniform-p "
                             f"ceiling {ceil:.4f}]")
                print(f"{'':12s}       spec     {sp['tokens_per_s']:9.1f} tok/s   "
                      f"accepted/step {sp['accepted_per_step']:.4f}   "
                      f"vs batched {sp['speedup_vs_batched']:5.2f}x{extra}")
                if args.min_accept > 0 and sp["accepted_per_step"] < args.min_accept:
                    failures.append(
                        f"{family} b={b}: spec accepted/step "
                        f"{sp['accepted_per_step']:.4f} < {args.min_accept:.4f}")
            if mesh is not None:
                # temperature threads through: the mesh row then measures
                # (and AR-gates) the FUSED sampled decode step — the
                # computation decode_step_hlo lowers at temperature > 0
                ms = bench_mode(family, "mesh", b, mesh=mesh,
                                tp_policy=args.tp_policy,
                                temperature=args.temperature)
                # only compare like with like: the single-device `bat`
                # baseline is greedy, so a sampled mesh row gets no
                # speedup column rather than a cross-mode ratio
                if bat is not None and args.temperature == 0:
                    ms["speedup_vs_batched"] = round(
                        ms["tokens_per_s"] / max(bat["tokens_per_s"], 1e-9), 2)
                rows.append(ms)
                print(f"{'':12s}       mesh     {ms['tokens_per_s']:9.1f} tok/s   "
                      f"partial-sum AR {ms['partial_sum_allreduces']}   "
                      f"({args.tp_policy})")
                if args.tp_policy == "cascade" and ms["partial_sum_allreduces"]:
                    failures.append(
                        f"{family} b={b}: cascade decode step contains "
                        f"{ms['partial_sum_allreduces']} partial-sum "
                        "all-reduce(s) — CASCADE invariant violated")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {args.out}")
    if failures:
        print("BENCH REGRESSION GATE FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

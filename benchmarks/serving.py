"""Serving-path benchmark: batched vs slot-wise continuous-batching decode.

Measures steady-state decode throughput of ``ServeEngine`` across batch
sizes, in both engine modes:

* ``slotwise`` — the legacy per-slot Python loop: one jitted ``decode_step``
  dispatch per resident request per token (weight streaming paid ``batch``
  times per engine step);
* ``batched``  — the stacked-cache grid: ONE donated, jitted ``decode_step``
  over all slots per engine step (weight streaming paid once — the paper's
  Table 9/10 batching balance).

Emits one JSON row per (mode, batch) into ``results/serving.json`` in the
same row style the roofline sweeps use (``arch``/``shape``/``status`` keys),
so ``benchmarks/report.py`` renders it alongside the other tables.

Run: PYTHONPATH=src:. python -m benchmarks.serving [--out results/serving.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ARCH = "codeqwen1.5-7b"
#: large enough that weight streaming (not dispatch overhead alone)
#: dominates a decode step, small enough for CPU CI
DIMS = dict(d_model=256, n_layers=4, d_ff=1024, vocab=2048,
            n_heads=8, n_kv_heads=8)
PROMPT_LEN = 16
MEASURE_STEPS = 24
WARMUP_STEPS = 3


def build_engine(batched: bool, max_batch: int):
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = dataclasses.replace(registry.get_config(ARCH, smoke=True), **DIMS)
    model = registry.build_model(cfg)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), ccfg)
    scfg = ServeConfig(max_batch=max_batch, max_len=128, batched=batched,
                       prefill_chunk=PROMPT_LEN)
    return cfg, ServeEngine(model, params, ccfg, scfg)


def bench_mode(batched: bool, max_batch: int) -> dict:
    from repro.serve.engine import Request

    cfg, eng = build_engine(batched, max_batch)
    rng = np.random.default_rng(0)
    for i in range(max_batch):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, PROMPT_LEN).astype(np.int32),
                           max_new_tokens=10_000))  # never retire during run
    for _ in range(1 + WARMUP_STEPS):       # admit-all step + jit warmup
        eng.step()
    assert all(s is not None for s in eng.slots)
    eng.step_times.clear()                  # drop trace/compile steps from p50/p99
    t0 = time.perf_counter()
    produced = 0
    for _ in range(MEASURE_STEPS):
        produced += eng.step()
    dt = time.perf_counter() - t0
    m = eng.metrics()
    return {
        "arch": ARCH,
        "shape": f"serve_decode_b{max_batch}",
        "mode": "batched" if batched else "slotwise",
        "status": "ok",
        "max_batch": max_batch,
        "decode_tokens": produced,
        "wall_s": round(dt, 4),
        "tokens_per_s": round(produced / dt, 2),
        "step_ms_p50": round(m["step_time_p50_s"] * 1e3, 2),
        "step_ms_p99": round(m["step_time_p99_s"] * 1e3, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/serving.json")
    ap.add_argument("--batches", type=int, nargs="*", default=[1, 2, 4, 8])
    args = ap.parse_args()

    rows = []
    for b in args.batches:
        slot = bench_mode(batched=False, max_batch=b)
        bat = bench_mode(batched=True, max_batch=b)
        speedup = bat["tokens_per_s"] / max(slot["tokens_per_s"], 1e-9)
        bat["speedup_vs_slotwise"] = slot["speedup_vs_slotwise"] = round(speedup, 2)
        rows += [slot, bat]
        print(f"b={b:2d}  slotwise {slot['tokens_per_s']:9.1f} tok/s   "
              f"batched {bat['tokens_per_s']:9.1f} tok/s   "
              f"speedup {speedup:5.2f}x")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {args.out}")


if __name__ == "__main__":
    main()

"""Serving-path benchmark: batched vs slot-wise continuous-batching decode,
across every registry arch family.

Measures steady-state decode throughput of ``ServeEngine`` across batch
sizes, in both engine modes:

* ``slotwise`` — the legacy per-slot Python loop: one jitted ``decode_step``
  dispatch per resident request per token (weight streaming paid ``batch``
  times per engine step);
* ``batched``  — the stacked-cache grid: ONE donated, jitted ``decode_step``
  over all slots per engine step (weight streaming paid once — the paper's
  Table 9/10 batching balance). Every family runs it over its own state:
  full KV, MLA latents, ring buffers + recurrent {conv, h}, SSD state.

Emits one JSON row per (arch, mode, batch) into ``--out`` in the same row
style the roofline sweeps use (``arch``/``shape``/``status`` keys), so
``benchmarks/report.py`` renders it alongside the other tables.

``--min-speedup X`` turns the run into a REGRESSION GATE: exit non-zero if
batched throughput is below X times slot-wise for any covered arch/batch
(CI runs this at 1.5x and uploads the JSON as a workflow artifact).

Run: PYTHONPATH=src:. python -m benchmarks.serving \
        [--archs transformer moe griffin ssm] [--batches 2]
        [--min-speedup 1.5] [--out results/bench_serving.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

#: per-family dimension overrides on top of the smoke config: large enough
#: that weight streaming (not dispatch overhead alone) dominates a decode
#: step, small enough for CPU CI
FAMILY_DIMS = {
    "transformer": dict(d_model=256, n_layers=4, d_ff=1024, vocab=2048,
                        n_heads=8, n_kv_heads=8),
    "moe": dict(d_model=256, n_layers=3, vocab=2048, moe_d_ff=512,
                dense_d_ff=1024, kv_lora=64, q_lora=96),
    "griffin": dict(d_model=256, n_layers=5, d_ff=768, vocab=2048,
                    lru_width=256, window=64),
    "ssm": dict(d_model=256, n_layers=4, d_inner=512, ssm_head_dim=64,
                vocab=2048),
}
PROMPT_LEN = 16
MEASURE_STEPS = 24
WARMUP_STEPS = 3
REPEATS = 3       # best-of-N throughput per mode: one noisy-neighbor burst
                  # on a shared CI runner must not fail the gate


def build_engine(family: str, batched: bool, max_batch: int):
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.serve.engine import ServeConfig, ServeEngine

    arch = registry.FAMILY_SMOKE[family]
    cfg = dataclasses.replace(registry.get_config(arch, smoke=True),
                              **FAMILY_DIMS[family])
    model = registry.build_model(cfg)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), ccfg)
    scfg = ServeConfig(max_batch=max_batch, max_len=128, batched=batched,
                       prefill_chunk=PROMPT_LEN)
    return cfg, ServeEngine(model, params, ccfg, scfg)


def bench_mode(family: str, batched: bool, max_batch: int) -> dict:
    from repro.serve.engine import Request

    cfg, eng = build_engine(family, batched, max_batch)
    rng = np.random.default_rng(0)
    for i in range(max_batch):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, PROMPT_LEN).astype(np.int32),
                           max_new_tokens=10_000))  # never retire during run
    for _ in range(1 + WARMUP_STEPS):       # admit-all step + jit warmup
        eng.step()
    assert all(s is not None for s in eng.slots)
    eng.step_times.clear()                  # drop trace/compile steps from p50/p99
    best_dt, produced = float("inf"), 0
    for _ in range(REPEATS):                # best-of-N: robust to CPU bursts
        t0 = time.perf_counter()
        rep = 0
        for _ in range(MEASURE_STEPS):
            rep += eng.step()
        dt = time.perf_counter() - t0
        if dt < best_dt:
            best_dt, produced = dt, rep
    dt = best_dt
    m = eng.metrics()
    return {
        "arch": cfg.name,
        "family": family,
        "shape": f"serve_decode_b{max_batch}",
        "mode": "batched" if batched else "slotwise",
        "status": "ok",
        "max_batch": max_batch,
        "decode_tokens": produced,
        "wall_s": round(dt, 4),
        "tokens_per_s": round(produced / dt, 2),
        "step_ms_p50": round(m["step_time_p50_s"] * 1e3, 2),
        "step_ms_p99": round(m["step_time_p99_s"] * 1e3, 2),
    }


def main():
    from repro.models import registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/bench_serving.json")
    ap.add_argument("--archs", nargs="*", default=sorted(registry.FAMILY_SMOKE),
                    choices=sorted(registry.FAMILY_SMOKE),
                    help="arch families to cover")
    ap.add_argument("--batches", type=int, nargs="*", default=[1, 2, 4, 8])
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail (exit 1) if batched/slotwise throughput falls "
                         "below this for any covered arch (0 = report only)")
    args = ap.parse_args()

    rows, failures = [], []
    for family in args.archs:
        for b in args.batches:
            slot = bench_mode(family, batched=False, max_batch=b)
            bat = bench_mode(family, batched=True, max_batch=b)
            speedup = bat["tokens_per_s"] / max(slot["tokens_per_s"], 1e-9)
            bat["speedup_vs_slotwise"] = slot["speedup_vs_slotwise"] = round(speedup, 2)
            rows += [slot, bat]
            print(f"{family:12s} b={b:2d}  "
                  f"slotwise {slot['tokens_per_s']:9.1f} tok/s   "
                  f"batched {bat['tokens_per_s']:9.1f} tok/s   "
                  f"speedup {speedup:5.2f}x")
            if args.min_speedup > 0 and speedup < args.min_speedup:
                failures.append(f"{family} b={b}: {speedup:.2f}x "
                                f"< {args.min_speedup:.2f}x")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {len(rows)} rows -> {args.out}")
    if failures:
        print("BENCH REGRESSION GATE FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

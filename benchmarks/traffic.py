"""Live-traffic bench: N replicas under a seeded Poisson trace, gated on SLO.

The serving bench measures steady-state decode throughput of one engine;
this bench measures what a client of the RACK sees — per-request TTFT and
inter-token latency percentiles, SLO attainment, shed/reject accounting —
when an open-loop arrival process the system does not control is routed
over ``elastic.ReplicaSet`` by ``serve/router.py``. Tail latency under
load is the metric that separates rack-scale serving from batch
benchmarks (ZettaLith Sections 2/19; the serving-scaling survey in
PAPERS.md), so this is the layer the CI ``traffic-slo`` leg gates.

Two clock modes:

* default (wall) — replicas step in real time, arrivals are real sleeps:
  the latency numbers are genuine wall-clock CPU-smoke measurements
  (noisy on shared runners; gate with generous margins);
* ``--virtual`` — a ``VirtualClock`` with a fixed ``--step-cost-ms`` per
  replica step: the run is DETERMINISTIC (same seed => byte-identical
  percentiles), so ``--min-slo-attainment`` can gate tightly in CI.

``--kill AT_S:REPLICA`` injects fail-in-place events mid-trace; the row
records them and the run still counts every stream's tokens (failover is
token-exact — pinned by tests/test_router.py, measured here).

Emits rows in the roofline/serving row style (``arch``/``shape``/
``status``/``mode`` keys) into ``--out``; ``benchmarks/report.py`` joins
``results/bench_traffic*.json`` into the SLO-attainment table.

Run: PYTHONPATH=src:. python -m benchmarks.traffic \
        [--arch transformer] [--replicas 2] [--rate 20] [--n-requests 48]
        [--slo-ttft 0.5] [--deadline 2.0] [--virtual --step-cost-ms 10]
        [--kill 0.5:0] [--min-slo-attainment 0.9] [--max-p99-ttft 10]
        [--out results/bench_traffic.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.serving import FAMILY_DIMS


def build_fleet(family: str, replicas: int, max_batch: int, max_len: int,
                clock, step_cost_ms: float = 0.0, prefix_cache: bool = False,
                page_size: int = 16, token_budget: int = 0):
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.serve.elastic import ReplicaSet
    from repro.serve.engine import ServeConfig, ServeEngine

    arch = registry.FAMILY_SMOKE[family]
    cfg = dataclasses.replace(registry.get_config(arch, smoke=True),
                              **FAMILY_DIMS[family])
    model = registry.build_model(cfg)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), ccfg)
    scfg = ServeConfig(max_batch=max_batch, max_len=max_len, batched=True,
                       prefill_chunk=16, prefix_cache=prefix_cache,
                       page_size=page_size, token_budget=token_budget)
    engines = [ServeEngine(model, params, ccfg, scfg, clock=clock)
               for _ in range(replicas)]
    cost = (lambda i: step_cost_ms * 1e-3) if step_cost_ms > 0 else None
    return cfg, ReplicaSet(engines, clock=clock, step_cost=cost)


def warm_cold_ttft(recs):
    """Split per-request TTFT into cold (first arrival of each shared
    prefix, plus every untagged prompt) and warm (later arrivals of an
    already-seen prefix — the radix cache should have it resident).

    Returns ``(warm_p50, cold_p50, n_warm, n_cold)``. The split is by
    arrival order, which is deterministic under a ``VirtualClock``."""
    seen = set()
    warm, cold = [], []
    for r in sorted(recs, key=lambda r: r.created_at):
        if r.first_token_at <= 0.0:
            continue
        ttft = r.first_token_at - r.created_at
        pid = getattr(r, "prefix_id", -1)
        if pid >= 0 and pid in seen:
            warm.append(ttft)
        else:
            cold.append(ttft)
            if pid >= 0:
                seen.add(pid)
    med = lambda a: float(np.percentile(np.asarray(a, np.float64), 50)) if a else 0.0
    return med(warm), med(cold), len(warm), len(cold)


def bench_traffic(args) -> dict:
    from repro.serve.router import SLORouter
    from repro.serve.traffic import (MonotonicClock, TrafficConfig,
                                     VirtualClock, poisson_trace)

    clock = VirtualClock() if args.virtual else MonotonicClock()
    cfg, rs = build_fleet(args.arch, args.replicas, args.max_batch,
                          args.max_len, clock,
                          step_cost_ms=(args.step_cost_ms if args.virtual
                                        else 0.0),
                          prefix_cache=args.prefix_cache,
                          page_size=args.page_size,
                          token_budget=args.token_budget)
    if not args.virtual:
        # wall mode: pay jit compile OUTSIDE the measured trace, or the
        # first request's TTFT is compile time, not serving time
        from repro.serve.engine import Request
        rng = np.random.default_rng(123)
        for i, eng in enumerate(rs.engines):
            eng.submit(Request(uid=10_000 + i,
                               prompt=rng.integers(0, cfg.vocab, 16)
                               .astype(np.int32), max_new_tokens=2))
        rs.drain(max_steps=500)
        for eng in rs.engines:
            eng._retired.clear()
            eng.step_times.clear()

    tcfg = TrafficConfig(rate_rps=args.rate, n_requests=args.n_requests,
                         prompt_lens=((4, 16), (24, 40)),
                         prompt_mix=(0.8, 0.2),
                         output_lens=((2, 6), (8, 16)),
                         output_mix=(0.7, 0.3),
                         vocab=cfg.vocab, slo_ttft_s=args.slo_ttft,
                         deadline_s=args.deadline,
                         shared_prefix_len=args.shared_prefix_len,
                         n_shared_prefixes=args.shared_count,
                         shared_fraction=args.shared_fraction,
                         seed=args.seed)
    kills = [(float(t), int(i)) for t, i in
             (k.split(":") for k in args.kill)]
    router = SLORouter(rs)
    router.run_trace(poisson_trace(tcfg), kills=kills)
    m = router.metrics()
    warm_p50, cold_p50, n_warm, n_cold = warm_cold_ttft(router.results())
    return {
        "prefix_cache": bool(args.prefix_cache),
        "page_size": args.page_size,
        "shared_prefix_len": args.shared_prefix_len,
        "prefix_hit_rate": round(m["prefix_hit_rate"], 6),
        "pages_in_use": m["pages_in_use"],
        "evictions": m["evictions"],
        "ttft_warm_p50_s": round(warm_p50, 6),
        "ttft_cold_p50_s": round(cold_p50, 6),
        "n_warm": n_warm,
        "n_cold": n_cold,
        "arch": cfg.name,
        "family": args.arch,
        "shape": f"traffic_r{args.replicas}_b{args.max_batch}",
        "mode": (("traffic-virtual" if args.virtual else "traffic")
                 + ("-prefix" if args.prefix_cache else "")),
        "status": "ok",
        "replicas": args.replicas,
        "max_batch": args.max_batch,
        "rate_rps": args.rate,
        "n_requests": args.n_requests,
        "slo_ttft_s": args.slo_ttft,
        "deadline_s": args.deadline,
        "seed": args.seed,
        "kills": [list(k) for k in kills],
        "step_cost_ms": args.step_cost_ms if args.virtual else None,
        "ttft_p50_s": round(m["ttft_p50_s"], 6),
        "ttft_p99_s": round(m["ttft_p99_s"], 6),
        "inter_token_p50_s": round(m["inter_token_p50_s"], 6),
        "inter_token_p99_s": round(m["inter_token_p99_s"], 6),
        "slo_attainment": round(m["slo_attainment"], 6),
        "requests_finished": m["requests_finished"],
        "requests_shed": m["requests_shed"],
        "requests_rejected": m["requests_rejected"],
        "replicas_alive": m["replicas_alive"],
    }


def main():
    from repro.models import registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/bench_traffic.json")
    ap.add_argument("--arch", default="transformer",
                    choices=sorted(registry.FAMILY_SMOKE))
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--slo-ttft", type=float, default=0.5,
                    help="per-request TTFT SLO in seconds (0 = none)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="admission deadline in seconds (0 = never shed)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--virtual", action="store_true",
                    help="deterministic VirtualClock run (same seed => "
                         "identical percentiles) instead of wall clock")
    ap.add_argument("--step-cost-ms", type=float, default=10.0,
                    help="virtual seconds one replica step costs (--virtual)")
    ap.add_argument("--kill", nargs="*", default=[], metavar="AT_S:REPLICA",
                    help="fail-in-place events, e.g. 0.5:0 kills replica 0 "
                         "half a second into the trace")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="serve with the paged KV pool + radix prefix cache")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--token-budget", type=int, default=0,
                    help="per-step prompt-token admission budget (0 = "
                         "unbounded). Set ~ the prefill chunk so cold "
                         "prefills span multiple (costed) steps and the "
                         "warm-vs-cold TTFT gap is measurable")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="shared-system-prompt mixture: prefix tokens "
                         "(0 = no mixture)")
    ap.add_argument("--shared-count", type=int, default=2,
                    help="distinct shared prefixes in the pool")
    ap.add_argument("--shared-fraction", type=float, default=0.9,
                    help="fraction of requests opening with a shared prefix")
    ap.add_argument("--min-prefix-hit", type=float, default=0.0,
                    help="fail (exit 1) below this prefix hit rate (0 = "
                         "report only)")
    ap.add_argument("--require-warm-ttft", action="store_true",
                    help="fail (exit 1) unless warm-prefix p50 TTFT beats "
                         "cold p50 TTFT")
    ap.add_argument("--min-slo-attainment", type=float, default=0.0,
                    help="fail (exit 1) below this SLO attainment (0 = "
                         "report only)")
    ap.add_argument("--max-p99-ttft", type=float, default=0.0,
                    help="fail (exit 1) if p99 TTFT exceeds this many "
                         "seconds (0 = report only)")
    args = ap.parse_args()

    row = bench_traffic(args)
    print(f"{args.arch:12s} r={args.replicas} rate={args.rate:g}/s  "
          f"ttft p50/p99 {row['ttft_p50_s']*1e3:.1f}/{row['ttft_p99_s']*1e3:.1f} ms  "
          f"inter-token p50/p99 {row['inter_token_p50_s']*1e3:.1f}/"
          f"{row['inter_token_p99_s']*1e3:.1f} ms  "
          f"SLO {row['slo_attainment']:.3f}  "
          f"fin/shed/rej {row['requests_finished']}/{row['requests_shed']}/"
          f"{row['requests_rejected']}")
    if args.prefix_cache or args.shared_prefix_len:
        print(f"{'':12s} prefix hit {row['prefix_hit_rate']:.3f}  "
              f"warm/cold ttft p50 {row['ttft_warm_p50_s']*1e3:.1f}/"
              f"{row['ttft_cold_p50_s']*1e3:.1f} ms "
              f"({row['n_warm']}/{row['n_cold']} reqs)  "
              f"pages {row['pages_in_use']}  evictions {row['evictions']}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump([row], f, indent=1)
    print(f"wrote 1 row -> {args.out}")

    failures = []
    if args.min_slo_attainment > 0 and (row["slo_attainment"]
                                        < args.min_slo_attainment):
        failures.append(f"SLO attainment {row['slo_attainment']:.3f} "
                        f"< {args.min_slo_attainment:.3f}")
    if args.max_p99_ttft > 0 and row["ttft_p99_s"] > args.max_p99_ttft:
        failures.append(f"p99 TTFT {row['ttft_p99_s']:.3f}s "
                        f"> {args.max_p99_ttft:.3f}s")
    if args.min_prefix_hit > 0 and row["prefix_hit_rate"] < args.min_prefix_hit:
        failures.append(f"prefix hit rate {row['prefix_hit_rate']:.3f} "
                        f"< {args.min_prefix_hit:.3f}")
    if args.require_warm_ttft and not (row["n_warm"] > 0
                                       and row["ttft_warm_p50_s"]
                                       < row["ttft_cold_p50_s"]):
        failures.append(f"warm p50 TTFT {row['ttft_warm_p50_s']:.4f}s not "
                        f"below cold {row['ttft_cold_p50_s']:.4f}s "
                        f"({row['n_warm']} warm / {row['n_cold']} cold)")
    if failures:
        print("TRAFFIC SLO GATE FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Analytic reproductions of the paper's tables.

Each function reproduces one table's derivation from first principles and
asserts agreement with the paper's published numbers. These are the
validation of the *faithful reproduction* (EXPERIMENTS.md §Paper-validation):
the paper has no code or measurements — its claims ARE these derivations.
"""
from __future__ import annotations

import math

# ---------------------------------------------------------------------------
# Table 6 / Section 13: CASCADE wave schedule
# ---------------------------------------------------------------------------

def cascade_schedule(batches: int = 32_768, rows_total: int = 24_576,
                     cols: int = 8_192, rows_per_array: int = 64,
                     hilt_load: int = 17, broadcast: int = 7,
                     sipo: int = 16, hilt_write: int = 4,
                     adder: str = "sequential") -> dict:
    """Cycle-accurate analytic model of one TRIMERA matmul wave (Table 6).

    sequential: partial sums ripple through all arrays (one clock per array).
    tree:       parallel adder-tree alternative (Section 13.3).
    """
    arrays = rows_total // rows_per_array
    fill = hilt_load + broadcast                      # clocks 1..24
    if adder == "sequential":
        pipe = rows_per_array + arrays                # 64 + 384
    else:
        pipe = rows_per_array + math.ceil(math.log2(arrays)) + 1
    first_done = fill + pipe                          # batch 1 complete
    last_done = first_done + batches - 1
    total = last_done + sipo + hilt_write
    useful = 2.0 * batches * rows_total * cols        # MAC = 2 ops
    capacity = 2.0 * total * rows_total * cols
    return {
        "arrays": arrays,
        "first_batch_done": first_done,
        "last_batch_done": last_done,
        "total_cycles": total,
        "useful_flops": useful,
        "efficiency": useful / capacity,
        "wave_us": total / 12e9 * 1e6,
    }


def bench_table6() -> dict:
    seq = cascade_schedule()
    tree = cascade_schedule(adder="tree")
    # Paper: 33,260 cycles total (2.77 us), 13,194,139,533,312 FLOPs, 98.52%;
    # adder-tree alternative: 32,885 cycles, 99.64%.
    assert abs(seq["total_cycles"] - 33_260) <= 1, seq["total_cycles"]
    assert seq["useful_flops"] == 13_194_139_533_312.0
    assert abs(seq["efficiency"] - 0.9852) < 2e-4, seq["efficiency"]
    assert abs(seq["wave_us"] - 2.77) < 0.01
    assert abs(tree["total_cycles"] - 32_885) <= 12, tree["total_cycles"]
    assert abs(tree["efficiency"] - 0.9964) < 1e-3
    return {"sequential_cycles": seq["total_cycles"],
            "sequential_eff": round(seq["efficiency"], 4),
            "tree_cycles": tree["total_cycles"],
            "tree_eff": round(tree["efficiency"], 4)}


# ---------------------------------------------------------------------------
# Tables 8/9/10: Llama 3.1 405B inference FLOPs & weight-loading balance
# ---------------------------------------------------------------------------

ZETTALITH_PEAK_SPARSE = 1_507_534e15        # FLOP/s (Table 2)
ZETTALITH_HBM_BW = 2.56e14                  # B/s (512 TW/s FP4 weights = 256 TB/s)

def llama31_405b_inference_ops(B: int = 1024, L: int = 2000) -> dict:
    """Reproduces Table 9 row-by-row (paper counts MACs as 'OPs', no x2)."""
    d, h, V, N = 16384, 128, 128_000, 80
    dff = 4 * d
    rows = {
        "embed_lookup": B * L * d,
        "rope": B * L * d,
        "ln_pre_attn": B * N * L * d,
        "qkv_proj": B * N * L * 3 * d * d,
        "attn_score": B * N * h * L * L,
        "softmax": B * N * h * L * L,
        "value_weight": B * N * h * L * L * (d // h),
        "out_proj": B * N * L * d * d,
        "residual_1": B * N * L * d,
        "ln_pre_ffn": B * N * L * d,
        "ffn_up": B * N * L * d * dff,
        "ffn_gate": B * N * L * d * dff,
        "swiglu": B * N * L * dff,
        "ffn_down": B * N * L * dff * d,
        "residual_2": B * N * L * d,
        "final_ln": B * L * d,
        "lm_head": B * 1 * d * V,
    }
    weights = {
        "embed": V * d,
        "ln_pre_attn": N * d,
        "qkv_proj": N * 3 * d * d,
        "out_proj": N * d * d,
        "ln_pre_ffn": N * d,
        "ffn_up": N * d * dff,
        "ffn_gate": N * d * dff,
        "ffn_down": N * dff * d,
        "final_ln": d,
        "lm_head": V * d,
    }
    total_ops = sum(rows.values())
    total_weights = sum(weights.values())
    return {"rows": rows, "weights": weights,
            "total_ops": total_ops, "total_weights": total_weights}


def bench_table9_10() -> dict:
    r = llama31_405b_inference_ops()
    # Paper: total 7.09E+17 OPs; total weights 3.48E+11; QKV row 1.32E+17;
    # FFN rows 1.76E+17 each; compute 0.00059 s at 80% peak; weights (FP4,
    # 0.5 B/weight) from HBM 0.00068 s at 2.56e14 B/s.
    assert abs(r["rows"]["qkv_proj"] / 1.32e17 - 1) < 0.01
    assert abs(r["rows"]["ffn_up"] / 1.76e17 - 1) < 0.01
    assert abs(r["total_ops"] / 7.09e17 - 1) < 0.01, r["total_ops"]
    assert abs(r["total_weights"] / 3.48e11 - 1) < 0.01, r["total_weights"]
    t_compute = r["total_ops"] / (0.8 * ZETTALITH_PEAK_SPARSE)
    t_weights = (r["total_weights"] * 0.5) / ZETTALITH_HBM_BW
    assert abs(t_compute / 0.00059 - 1) < 0.02, t_compute
    assert abs(t_weights / 0.00068 - 1) < 0.02, t_weights
    # the paper's point: at B=1024 the two are balanced (within ~15%)
    assert 0.5 < t_compute / t_weights < 1.5
    return {"total_ops": r["total_ops"], "total_weights": r["total_weights"],
            "t_compute_s": round(t_compute, 6), "t_weights_s": round(t_weights, 6)}


def balanced_batch_size(peak_flops: float, hbm_bw: float, mfu: float = 0.8,
                        weight_bytes_per_param: float = 0.5) -> float:
    """The paper's weight-reuse rule (Section 14.2) generalized: the DECODE
    batch size B* at which compute time equals weight-streaming time.
    Per step: compute 2*N*B FLOPs, stream w*N bytes; N and the chip count
    cancel:  B* = (w/2) * mfu * peak / bw.  ZettaLith (Table 10): ~1,024;
    one TPU v5e chip at FP4 weights: ~48."""
    return (weight_bytes_per_param / 2.0) * mfu * peak_flops / hbm_bw


# ---------------------------------------------------------------------------
# Tables 1 & 20: rack-level comparison
# ---------------------------------------------------------------------------

def bench_table1_20() -> dict:
    # Table 20 raw values
    gpu = {"pflops_sparse": 1_440, "power_kw": 120, "pe_cycles_phz": 360,
           "fabric_tbs": 259, "accelerators": 72}
    zl = {"pflops_sparse": 1_507_534, "power_kw": 84.305, "pe_cycles_phz": 376_883,
          "fabric_tbs": 7_800, "accelerators": 156}
    perf_ratio = zl["pflops_sparse"] / gpu["pflops_sparse"]
    power_eff_ratio = (zl["pflops_sparse"] / zl["power_kw"]) / \
        (gpu["pflops_sparse"] / gpu["power_kw"])
    assert abs(perf_ratio / 1047 - 1) < 0.01, perf_ratio
    assert abs(power_eff_ratio / 1490 - 1) < 0.01, power_eff_ratio

    # Table 1 factor products ("sanity check" per the paper)
    perf_factors = [3.86, 2.08, 2.94, 1.92, 3.12, 3.91, 1.00, 1.89]
    pw_factors = [3.86, 1.28, 2.94, 1.92, 4.37, 4.75, 1.00, 2.56]
    cost_factors = [3.86, 2.08, 2.94, 2.97, 4.37, 3.91, 1.51, 1.29]
    pf = math.prod(perf_factors)
    pwf = math.prod(pw_factors)
    cf = math.prod(cost_factors)
    # the paper adjusts factors so products match the direct totals
    assert abs(pf / 1047 - 1) < 0.02, pf
    assert abs(pwf / 1490 - 1) < 0.02, pwf
    assert abs(cf / 2325 - 1) < 0.02, cf
    return {"perf_ratio": round(perf_ratio, 1), "power_eff_ratio": round(power_eff_ratio, 1),
            "factor_products": [round(pf, 0), round(pwf, 0), round(cf, 0)]}


# ---------------------------------------------------------------------------
# Tables 2/4/5: PE area/power/performance chain
# ---------------------------------------------------------------------------

def bench_pe_model() -> dict:
    # Table 4: area
    a16_density_mtr_mm2 = 344.0
    transistors = 505
    min_area_um2 = transistors / a16_density_mtr_mm2  # MTr/mm^2 == Tr/um^2
    full_custom = min_area_um2 / 2.1
    assert abs(min_area_um2 - 1.47) < 0.01
    assert abs(full_custom - 0.70) < 0.005

    # Table 5: power  P = alpha * C * V^2 * f
    c_fF = 46.0 / 2.2                 # full-custom optimized capacitance
    v, f = 0.7, 12e9
    sparsity = 0.90
    alpha = 0.10 * (1 - sparsity) + 0.04 * sparsity   # = 0.046
    peak_use = 0.753
    p_n3e = alpha * (c_fF * 1e-15) * v * v * f * peak_use
    assert abs(p_n3e / 4.3e-6 - 1) < 0.03, p_n3e
    p_a16 = p_n3e * 0.53
    assert abs(p_a16 / 2.3e-6 - 1) < 0.05, p_a16

    # Table 2: performance chain
    pes_per_sld = 203e6                               # power/area limited
    pe_gflops = 2 * 12e9                              # 1 MAC = 2 ops @12GHz
    sld_dense = pes_per_sld * pe_gflops               # ~4.87e18
    active_rows, active_cols, arrays = 64, 8192, 384
    active_pes = active_rows * active_cols * arrays   # 201,326,592
    trimera_dense = active_pes * pe_gflops
    zl_dense = trimera_dense * 156
    assert abs(zl_dense / 753e18 - 1) < 0.01, zl_dense
    zl_sparse = 2 * zl_dense
    assert abs(zl_sparse / 1.507e21 - 1) < 0.01
    total_pes = active_pes * 156
    assert total_pes == 31_406_948_352                # Section 12.2
    pe_power_kw = total_pes * p_a16 / 1e3
    assert abs(pe_power_kw / 72 - 1) < 0.05, pe_power_kw
    return {"pe_area_um2": round(full_custom, 2), "pe_power_uw": round(p_a16 * 1e6, 2),
            "zl_dense_exaflops": round(zl_dense / 1e18, 1),
            "zl_sparse_exaflops": round(zl_sparse / 1e18, 1),
            "total_pes": total_pes, "pe_power_kw": round(pe_power_kw, 1)}

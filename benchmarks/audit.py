"""Serving-contract audit CLI: repo lint + the full audit_engine matrix.

Runs both halves of ``repro.analysis`` and writes one JSON file
(``results/audit.json``, rendered by ``benchmarks/report.py``):

* **lint** — the AST rules over ``src/repro`` (``analysis/lint.py``).
* **cells** — ``analysis.contract.audit_engine`` over every constructed
  step closure, across family × {dense, fused, paged} × {single-device,
  mesh}. Unsupported combinations are not silently skipped: the engine is
  still constructed and the cell records the downgrade it warned about
  (``status: "downgraded"``), so "this combination was never checked"
  is itself a checked fact. Audited single-device cells also serve a tiny
  trace first and run the ``analysis.retrace`` compile-count guard
  (``--no-retrace`` to skip; mesh cells skip it by default — an 8-virtual-
  device trace is all compile time).

Engines are built with ``temperature > 0`` and ``draft_len > 0`` so EVERY
closure materializes (decode, extend, write, verify, rewind, sample,
spec_sample, plus the paged page ops). Exit status 1 on any unallowlisted
error finding — the CI ``static-analysis`` leg gates on it.

Known limitations (measured facts the engine never promised, kept VISIBLE
as allowlisted findings rather than silently relaxed — see
``docs/analysis.md``):

* griffin × mesh: GSPMD full-rematerialization on the ring cache drops
  buffer aliasing (donation findings allowlisted for that cell).

Usage:
    PYTHONPATH=src python -m benchmarks.audit [--mesh 4x2] \
        [--families transformer moe] [--modes dense fused paged] \
        [--host-devices 8] [--out results/audit.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import warnings
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# (check, family, placement) patterns whose findings are allowlisted with
# a reason; keep this table SHORT and documented — every entry is a debt
KNOWN_LIMITATIONS = (
    {"check": "donation", "family": "griffin", "placement": "mesh",
     "reason": "GSPMD full-remat on the ring cache under mesh drops "
               "aliasing (tracked in ROADMAP)"},
)


def _mark_known(cell: dict, findings) -> None:
    for f in findings:
        for k in KNOWN_LIMITATIONS:
            if (f.check == k["check"] and cell["family"] == k["family"]
                    and cell["placement"] == k["placement"]
                    and not f.allowlisted):
                f.allowlisted = True
                f.detail += f" [known limitation: {k['reason']}]"


def _build_engine(arch: str, mode: str, mesh, tp_policy: str):
    import jax
    import jax.numpy as jnp
    from repro.core import cascade
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg, model = registry.load(arch, smoke=True)
    train_ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), train_ccfg)
    ccfg = train_ccfg
    if mode == "fused":
        ccfg = CascadeConfig(mode="serve_fp4", compute_dtype=jnp.float32)
        params = cascade.tree_to_serve_fp4(params, ccfg)
    scfg = ServeConfig(max_batch=8 if mesh is not None else 4, max_len=48,
                       temperature=0.7, draft_len=2, prefill_chunk=8,
                       tp_policy=tp_policy, fused=(mode == "fused"),
                       prefix_cache=(mode == "paged"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        eng = ServeEngine(model, params, ccfg, scfg, mesh=mesh)
    return cfg, eng


def _trace(cfg, eng, n_requests: int = 6) -> None:
    """Serve a tiny trace so every hot closure dispatches (and would
    retrace if shapes leaked)."""
    import numpy as np
    from repro.serve.engine import Request
    rng = np.random.default_rng(0)
    for i in range(n_requests):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
            max_new_tokens=6))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        while eng.busy():
            eng.step()


def run_cell(family: str, arch: str, mode: str, placement: str, mesh,
             tp_policy: str, retrace_on: bool) -> dict:
    """One matrix cell: construct, verify mode actually engaged (or record
    the downgrade), trace + retrace, then lower + audit every closure."""
    from repro.analysis import contract, retrace

    cell = {"family": family, "arch": arch, "mode": mode,
            "placement": placement, "tp_policy": tp_policy,
            "status": "audited", "downgrades": [], "closures": {},
            "findings": []}
    cfg, eng = _build_engine(arch, mode, mesh, tp_policy)
    cell["downgrades"] = list(eng.downgrades)
    engaged = {"dense": True, "fused": eng.fused, "paged": eng.paged}[mode]
    if not engaged:
        # the combination downgraded at construction — record WHY (the
        # warn-once message) so a silently-skipped cell cannot exist
        cell["status"] = "downgraded"
        return cell
    findings = []
    if retrace_on:
        _trace(cfg, eng)
        findings.extend(retrace.retrace_findings(
            eng, require_dispatched=("extend",)))
    res = contract.audit_engine(eng)
    findings.extend(res["findings"])
    _mark_known(cell, findings)
    cell["closures"] = res["closures"]
    cell["contract"] = res["contract"]
    cell["findings"] = [f.to_dict() for f in findings]
    return cell


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repo lint + serving-contract audit matrix")
    ap.add_argument("--families", nargs="*", default=None,
                    help="registry families to audit (default: all four)")
    ap.add_argument("--modes", nargs="*",
                    default=["dense", "fused", "paged"],
                    choices=["dense", "fused", "paged"])
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="also audit mesh cells on a (data, model) mesh, "
                         "e.g. 4x2 (needs the devices; see --host-devices)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N virtual CPU devices (before first jax use)")
    ap.add_argument("--tp-policy", default="cascade",
                    choices=["cascade", "megatron"])
    ap.add_argument("--no-retrace", action="store_true",
                    help="skip the trace + compile-count guard")
    ap.add_argument("--no-lint", action="store_true")
    ap.add_argument("--out", default="results/audit.json")
    args = ap.parse_args(argv)

    from repro.launch import mesh as meshlib
    if args.host_devices:
        meshlib.force_host_device_count(args.host_devices)

    from repro.analysis.findings import Finding, format_findings, gating
    from repro.analysis.lint import lint_paths
    from repro.models.registry import FAMILY_SMOKE

    out = {"lint": [], "cells": [], "summary": {}}
    all_findings = []

    if not args.no_lint:
        lint_findings = lint_paths(["src/repro"], base=REPO)
        out["lint"] = [f.to_dict() for f in lint_findings]
        all_findings.extend(lint_findings)
        print(f"lint: {len(lint_findings)} finding(s), "
              f"{len(gating(lint_findings))} gating")

    families = args.families or list(FAMILY_SMOKE)
    placements = [("single", None)]
    if args.mesh:
        placements.append(("mesh", meshlib.make_serving_mesh(args.mesh)))

    for placement, mesh in placements:
        for family in families:
            arch = FAMILY_SMOKE[family]
            for mode in args.modes:
                retrace_on = (not args.no_retrace) and placement == "single"
                cell = run_cell(family, arch, mode, placement, mesh,
                                args.tp_policy, retrace_on)
                out["cells"].append(cell)
                fs = [Finding.from_dict(d) for d in cell["findings"]]
                all_findings.extend(fs)
                g = len(gating(fs))
                print(f"{family}/{mode}/{placement}: {cell['status']}, "
                      f"{len(cell['closures'])} closure(s), "
                      f"{len(fs)} finding(s), {g} gating")
                if g:
                    print(format_findings(gating(fs)))

    bad = gating(all_findings)
    out["summary"] = {
        "cells": len(out["cells"]),
        "audited": sum(1 for c in out["cells"] if c["status"] == "audited"),
        "downgraded": sum(1 for c in out["cells"]
                          if c["status"] == "downgraded"),
        "findings": len(all_findings),
        "gating": len(bad),
    }
    outp = Path(args.out)
    if not outp.is_absolute():
        outp = REPO / outp
    outp.parent.mkdir(parents=True, exist_ok=True)
    outp.write_text(json.dumps(out, indent=2, default=str))
    print(f"wrote {outp} — {out['summary']}")
    if bad:
        print(f"\nAUDIT FAILED: {len(bad)} unallowlisted finding(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

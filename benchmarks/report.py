"""Render the §Roofline tables (baseline / faithful / optimized) as markdown.

Run after the sweeps:
  PYTHONPATH=src:. python -m benchmarks.report > results/roofline_report.md
"""
from __future__ import annotations

import json
import os


def load(path):
    if not os.path.exists(path):
        return {}
    return {(r["arch"], r["shape"]): r for r in json.load(open(path))}


def main():
    base = load("results/roofline_baseline.json")
    faith = load("results/roofline_faithful.json")
    opt = load("results/roofline_optimized.json")

    print("# Roofline report (single-pod 16x16, per-device per-step)\n")
    print("fraction = compute term / dominant term; terms in seconds.\n")
    hdr = ("| arch | shape | baseline frac | faithful frac | optimized frac | "
           "opt dominant | opt compute_s | opt memory_s | opt collective_s | "
           "opt peak GB | useful ratio |")
    print(hdr)
    print("|" + "---|" * 11)
    for key in sorted(base.keys()):
        b = base[key]
        if b["status"] == "skipped":
            print(f"| {key[0]} | {key[1]} | N/A (full attention, long_500k) "
                  f"| | | | | | | | |")
            continue
        f = faith.get(key, {})
        o = opt.get(key, {})
        fo = o.get("roofline_fraction", "—") if o.get("status") == "ok" else "FAIL"
        ff = f.get("roofline_fraction", "—") if f.get("status") == "ok" else "FAIL"
        t = o.get("terms_s", {})
        mem = o.get("memory", {}) or {}
        peak = (mem.get("peak_bytes") or 0) / 1e9
        print(f"| {key[0]} | {key[1]} | {b['roofline_fraction']} | {ff} | {fo} "
              f"| {o.get('dominant','—')} | {t.get('compute','—')} | {t.get('memory','—')} "
              f"| {t.get('collective','—')} | {peak:.1f} | {o.get('useful_flops_ratio','—')} |")

    # serving: batched vs slot-wise continuous-batching decode (+ spec), per
    # family. Loading is schema-tolerant: rows from earlier PRs may lack the
    # spec columns (or even max_batch/mode) and must still render.
    serving_path = next((p for p in ("results/bench_serving.json",
                                     "results/serving.json")
                         if os.path.exists(p)), None)
    if serving_path:
        rows = json.load(open(serving_path))
        print("\n## Serving decode throughput (benchmarks/serving.py)\n")
        print("| family | batch | slotwise tok/s | batched tok/s | speedup "
              "| batched p99 step ms | spec tok/s | accepted/step | spec vs batched |")
        print("|" + "---|" * 9)
        by_key = {}
        for r in rows:
            key = (r.get("family", r.get("arch", "?")), r.get("max_batch", "?"))
            by_key.setdefault(key, {})[r.get("mode", "?")] = r
        # numeric batches sort numerically; legacy rows without max_batch
        # (non-int placeholder) sort after them
        for fam, b in sorted(by_key, key=lambda t: (
                str(t[0]), (0, t[1]) if isinstance(t[1], int) else (1, str(t[1])))):
            s = by_key[(fam, b)].get("slotwise", {})
            k = by_key[(fam, b)].get("batched", {})
            p = by_key[(fam, b)].get("spec", {})
            print(f"| {fam} | {b} | {s.get('tokens_per_s','—')} "
                  f"| {k.get('tokens_per_s','—')} "
                  f"| {k.get('speedup_vs_slotwise','—')}x | {k.get('step_ms_p99','—')} "
                  f"| {p.get('tokens_per_s','—')} | {p.get('accepted_per_step','—')} "
                  f"| {p.get('speedup_vs_batched','—')}x |")

    # CASCADE invariant check: forward graphs with zero all-reduce bytes
    print("\n## CASCADE zero-partial-sum invariant (faithful preset)\n")
    viol = []
    for key, f in faith.items():
        if f.get("status") != "ok" or key[1] == "train_4k":
            continue
        ar = f["collectives_corrected"]["all-reduce"]["bytes"]
        if ar > 1e9:
            viol.append((key, ar))
    if viol:
        print("all-reduce >1GB found in (MoE dispatch reductions — see DESIGN.md):")
        for (a, s), ar in viol:
            print(f"- {a} x {s}: {ar/1e9:.1f} GB")
    else:
        print("No serving-graph all-reduce above 1 GB/device anywhere.")


if __name__ == "__main__":
    main()

"""Render the §Roofline tables (baseline / faithful / optimized) as markdown.

Run after the sweeps:
  PYTHONPATH=src:. python -m benchmarks.report > results/roofline_report.md
"""
from __future__ import annotations

import json
import os


def load(path):
    """Roofline rows keyed by (arch, shape). Schema-tolerant: rows from
    older sweeps (or hand-edited files) may lack ``arch``/``shape`` — they
    key under '?' instead of KeyError-ing the whole report away."""
    if not os.path.exists(path):
        return {}
    return {(r.get("arch", "?"), r.get("shape", "?")): r
            for r in json.load(open(path))}


def serving_rows():
    """All serving-bench rows: the main artifact plus the sibling files the
    CI mesh / spec-sampling / fused-decode legs write (kept separate so the
    single-device gate artifact stays byte-stable)."""
    serving_path = next((p for p in ("results/bench_serving.json",
                                     "results/serving.json")
                         if os.path.exists(p)), None)
    rows = json.load(open(serving_path)) if serving_path else []
    for extra in ("results/bench_serving_mesh.json",
                  "results/bench_serving_sampled.json",
                  "results/bench_serving_fused.json"):
        if os.path.exists(extra):
            rows += json.load(open(extra))
    return rows


def traffic_rows():
    """All live-traffic bench rows: every ``results/bench_traffic*.json``
    (the CI traffic-slo leg writes virtual + wall siblings). Kept out of
    ``serving_rows`` — traffic rows have no tokens/s column and would
    render as dashes in the throughput table."""
    import glob
    rows = []
    for p in sorted(glob.glob("results/bench_traffic*.json")):
        rows += json.load(open(p))
    return rows


def traffic_lines(rows):
    """Markdown lines for the SLO-attainment table ('' if no traffic rows).
    Schema-tolerant like the other loaders: missing latency/accounting
    fields render as dashes, not KeyErrors."""
    trows = [r for r in rows if str(r.get("mode", "")).startswith("traffic")]
    if not trows:
        return []

    def ms(r, k):
        v = r.get(k)
        return f"{v * 1e3:.1f}" if isinstance(v, (int, float)) else "—"

    lines = [
        "",
        "## Live traffic: SLO attainment under open-loop load "
        "(benchmarks/traffic.py)",
        "",
        "Open-loop Poisson arrivals routed over N replicas by "
        "serve/router.py; TTFT charges queueing delay from ARRIVAL, not "
        "dispatch, and shed/rejected requests count as SLO misses. "
        "'virtual' rows run the deterministic VirtualClock (same seed => "
        "identical percentiles — the gateable numbers); wall rows are "
        "CPU-smoke real time.",
        "",
        "| family | replicas | batch | rate req/s | clock "
        "| ttft p50/p99 ms | inter-token p50/p99 ms | SLO attainment "
        "| finished/shed/rejected | kills |",
        "|" + "---|" * 10,
    ]
    for r in sorted(trows, key=lambda x: (str(x.get("family", "?")),
                                          str(x.get("mode", "?")),
                                          str(x.get("replicas", "?")))):
        clock = ("virtual" if r.get("mode") == "traffic-virtual" else "wall")
        acct = (f"{r.get('requests_finished', '—')}/"
                f"{r.get('requests_shed', '—')}/"
                f"{r.get('requests_rejected', '—')}")
        kills = len(r.get("kills") or []) or "—"
        lines.append(
            f"| {r.get('family', '?')} | {r.get('replicas', '—')} "
            f"| {r.get('max_batch', '—')} | {r.get('rate_rps', '—')} "
            f"| {clock} | {ms(r, 'ttft_p50_s')}/{ms(r, 'ttft_p99_s')} "
            f"| {ms(r, 'inter_token_p50_s')}/{ms(r, 'inter_token_p99_s')} "
            f"| {r.get('slo_attainment', '—')} | {acct} | {kills} |")
    return lines


def prefix_lines(serving, traffic):
    """Markdown lines for the prefix-cache warm-vs-cold TTFT table ('' if
    no paged/prefix rows anywhere). Two row sources, both schema-tolerant:

    * ``prefix_probe`` rows from ``benchmarks/serving.py --paged`` — a
      same-engine cold-then-warm prefill probe (report-only numbers);
    * ``*-prefix`` traffic rows from ``benchmarks/traffic.py
      --prefix-cache`` — warm/cold TTFT p50 split by arrival order under
      the open-loop shared-system-prompt trace (the CI-gated numbers).

    Rows missing any of the new keys (``prefix_hit_rate``,
    ``ttft_warm_p50_s``, ...) render dashes, never KeyError."""
    probes = [r for r in serving if r.get("shape") == "prefix_probe"]
    trows = [r for r in traffic
             if str(r.get("mode", "")).endswith("-prefix")
             or "ttft_warm_p50_s" in r]
    if not probes and not trows:
        return []

    def ms(r, k):
        v = r.get(k)
        return f"{v * 1e3:.1f}" if isinstance(v, (int, float)) else "—"

    def ratio(r, warm_k, cold_k):
        w, c = r.get(warm_k), r.get(cold_k)
        if isinstance(w, (int, float)) and isinstance(c, (int, float)) and w > 0:
            return f"{c / w:.2f}x"
        return "—"

    lines = [
        "",
        "## Prefix cache: warm vs cold TTFT (paged KV pool + radix tree)",
        "",
        "A warm request's shared prefix is already resident in the page "
        "pool, so admission re-pins pages and prefills only the unique "
        "suffix; a cold request pays full prefill. Probe rows are a "
        "same-engine A/B (benchmarks/serving.py --paged); traffic rows "
        "split the open-loop shared-system-prompt trace by arrival order "
        "(benchmarks/traffic.py --prefix-cache — the CI-gated numbers).",
        "",
        "| source | family | shared prefix | cold ttft ms | warm ttft ms "
        "| cold/warm | hit rate | pages | evictions |",
        "|" + "---|" * 9,
    ]
    for r in sorted(probes, key=lambda x: str(x.get("family", "?"))):
        lines.append(
            f"| probe | {r.get('family', '?')} | {r.get('prefix_len', '—')} "
            f"| {ms(r, 'ttft_cold_s')} | {ms(r, 'ttft_warm_s')} "
            f"| {ratio(r, 'ttft_warm_s', 'ttft_cold_s')} "
            f"| {r.get('prefix_hit_rate', '—')} "
            f"| {r.get('pages_in_use', '—')} | {r.get('evictions', '—')} |")
    for r in sorted(trows, key=lambda x: (str(x.get("family", "?")),
                                          str(x.get("mode", "?")))):
        clock = "virtual" if "virtual" in str(r.get("mode", "")) else "wall"
        lines.append(
            f"| traffic ({clock}) | {r.get('family', '?')} "
            f"| {r.get('shared_prefix_len', '—')} "
            f"| {ms(r, 'ttft_cold_p50_s')} | {ms(r, 'ttft_warm_p50_s')} "
            f"| {ratio(r, 'ttft_warm_p50_s', 'ttft_cold_p50_s')} "
            f"| {r.get('prefix_hit_rate', '—')} "
            f"| {r.get('pages_in_use', '—')} | {r.get('evictions', '—')} |")
    return lines


def fused_lines(rows):
    """Markdown lines for the fused-FP4 measured-vs-bound table ('' if no
    fused rows). Tolerant of rows missing the bound fields: a fused row
    without ``weight_stream_bytes_per_device`` renders with a 0.00 GB cell
    instead of dropping the table."""
    frows = [r for r in rows if r.get("mode") == "fused"]
    if not frows:
        return []
    lines = [
        "",
        "## Fused FP4 decode: measured vs weight-streaming bound "
        "(smoke models)",
        "",
        "bound = max_batch / (weight bytes / HBM bw): the ceiling where "
        "decode streams every live weight byte exactly once per step. "
        "Measured rows are CPU interpret-mode smoke numbers; the ratio "
        "becomes meaningful on TPU.",
        "",
        "| family | batch | fused tok/s | fp4 jnp tok/s | kernel speedup "
        "| weight-stream GB/dev | bound tok/s | measured/bound |",
        "|" + "---|" * 8,
    ]
    jnp_by_key = {(r.get("family", "?"), r.get("max_batch", "?")):
                  r.get("tokens_per_s", "—")
                  for r in rows if r.get("mode") == "fp4"}
    for r in sorted(frows, key=lambda x: (str(x.get("family", "?")),
                                          str(x.get("max_batch", "?")))):
        key = (r.get("family", "?"), r.get("max_batch", "?"))
        gb = (r.get("weight_stream_bytes_per_device") or 0) / 1e9
        lines.append(
            f"| {key[0]} | {key[1]} | {r.get('tokens_per_s', '—')} "
            f"| {jnp_by_key.get(key, '—')} "
            f"| {r.get('speedup_vs_fp4_jnp', '—')}x "
            f"| {gb:.2f} | {r.get('decode_bound_tokens_per_s', '—')} "
            f"| {r.get('fraction_of_bound', '—')} |")
    return lines


def audit_data(path="results/audit.json"):
    """The serving-contract audit artifact (benchmarks/audit.py), or {} when
    absent/unreadable — the report must render without the static-analysis
    leg having run."""
    if not os.path.exists(path):
        return {}
    try:
        data = json.load(open(path))
    except Exception:
        return {}
    return data if isinstance(data, dict) else {}


def audit_lines(data):
    """Markdown lines for the serving-contract audit table ('' if no
    artifact). Schema-tolerant: cells from older audit runs may lack
    ``closures``/``findings``/``summary`` fields and must render with
    dashes, never KeyError."""
    cells = data.get("cells") or []
    if not cells:
        return []

    def level_counts(fs):
        gating = sum(1 for f in fs if f.get("level", "error") == "error"
                     and not f.get("allowlisted"))
        allowed = sum(1 for f in fs if f.get("allowlisted"))
        info = sum(1 for f in fs if f.get("level") == "info"
                   and not f.get("allowlisted"))
        return gating, allowed, info

    lint = data.get("lint") or []
    lg, la, li = level_counts(lint)
    lines = [
        "",
        "## Serving contract: static HLO audit (benchmarks/audit.py)",
        "",
        "Every jitted step closure AOT-lowered and checked against the "
        "serving contract (donation honored, no host round-trips, no "
        "forbidden dtypes, packed FP4 weights, collective budget) — see "
        "docs/analysis.md for the invariant table. 'aliases' sums donation "
        "alias entries across closures; 'psum AR' sums partial-sum "
        "all-reduces (0 is the cascade claim holding). Downgraded cells "
        "record combinations the engine refused with a warning — checked "
        "facts, not skips.",
        "",
        f"repo lint: {len(lint)} finding(s) — {lg} gating, {la} "
        f"allowlisted, {li} info",
        "",
        "| family | mode | placement | status | closures | aliases "
        "| host xfer | psum AR | findings (gate/allow/info) |",
        "|" + "---|" * 9,
    ]
    for c in sorted(cells, key=lambda x: (str(x.get("placement", "?")),
                                          str(x.get("family", "?")),
                                          str(x.get("mode", "?")))):
        cl = c.get("closures") or {}
        fs = c.get("findings") or []
        g, a, i = level_counts(fs)

        def tot(key):
            vals = [s.get(key) for s in cl.values()
                    if isinstance(s.get(key), (int, float))]
            return int(sum(vals)) if vals else "—"

        lines.append(
            f"| {c.get('family', '?')} | {c.get('mode', '?')} "
            f"| {c.get('placement', '?')} | {c.get('status', '?')} "
            f"| {len(cl) or '—'} | {tot('donation_aliases')} "
            f"| {tot('host_transfers')} | {tot('partial_sum_allreduces')} "
            f"| {g}/{a}/{i} |")
    summ = data.get("summary") or {}
    if summ:
        lines.append(
            f"\naudit summary: {summ.get('audited', '—')} audited + "
            f"{summ.get('downgraded', '—')} downgrade-verified cells, "
            f"{summ.get('gating', '—')} gating finding(s).")
    return lines


def main():
    base = load("results/roofline_baseline.json")
    faith = load("results/roofline_faithful.json")
    opt = load("results/roofline_optimized.json")

    print("# Roofline report (single-pod 16x16, per-device per-step)\n")
    print("fraction = compute term / dominant term; terms in seconds.\n")
    hdr = ("| arch | shape | baseline frac | faithful frac | optimized frac | "
           "opt dominant | opt compute_s | opt memory_s | opt collective_s | "
           "opt peak GB | useful ratio |")
    print(hdr)
    print("|" + "---|" * 11)
    for key in sorted(base.keys()):
        b = base[key]
        if b["status"] == "skipped":
            print(f"| {key[0]} | {key[1]} | N/A (full attention, long_500k) "
                  f"| | | | | | | | |")
            continue
        f = faith.get(key, {})
        o = opt.get(key, {})
        fo = o.get("roofline_fraction", "—") if o.get("status") == "ok" else "FAIL"
        ff = f.get("roofline_fraction", "—") if f.get("status") == "ok" else "FAIL"
        t = o.get("terms_s", {})
        mem = o.get("memory", {}) or {}
        peak = (mem.get("peak_bytes") or 0) / 1e9
        print(f"| {key[0]} | {key[1]} | {b['roofline_fraction']} | {ff} | {fo} "
              f"| {o.get('dominant','—')} | {t.get('compute','—')} | {t.get('memory','—')} "
              f"| {t.get('collective','—')} | {peak:.1f} | {o.get('useful_flops_ratio','—')} |")

    # serving: batched vs slot-wise continuous-batching decode (+ spec), per
    # family. Loading is schema-tolerant: rows from earlier PRs may lack the
    # spec columns (or even max_batch/mode) and must still render.
    rows = serving_rows()
    if rows:
        print("\n## Serving decode throughput (benchmarks/serving.py)\n")
        print("accepted/step for sampled spec rows is bounded by the model's "
              "own probability mass on the drafts (uniform p on the zeroed "
              "head => ceiling sum V^-j), not by the greedy ceiling K.\n")
        print("| family | batch | slotwise tok/s | batched tok/s | speedup "
              "| batched p99 step ms | spec tok/s | accepted/step | spec vs batched "
              "| sampled-spec tok/s | accepted/step (T, V) "
              "| mesh tok/s | partial-sum AR |")
        print("|" + "---|" * 13)
        by_key = {}
        for r in rows:
            if r.get("mode") in ("fp4", "fused", "paged"):
                continue  # rendered in their own tables (fused_lines /
                          # prefix_lines)
            key = (r.get("family", r.get("arch", "?")), r.get("max_batch", "?"))
            # sampled spec rows (temperature > 0) render in their own
            # columns; greedy spec rows keep the legacy 'spec' slot
            mode = r.get("mode", "?")
            if mode == "spec" and r.get("temperature", 0) > 0:
                mode = "spec_sampled"
            by_key.setdefault(key, {})[mode] = r
        # numeric batches sort numerically; legacy rows without max_batch
        # (non-int placeholder) sort after them
        for fam, b in sorted(by_key, key=lambda t: (
                str(t[0]), (0, t[1]) if isinstance(t[1], int) else (1, str(t[1])))):
            s = by_key[(fam, b)].get("slotwise", {})
            k = by_key[(fam, b)].get("batched", {})
            p = by_key[(fam, b)].get("spec", {})
            ps = by_key[(fam, b)].get("spec_sampled", {})
            m = by_key[(fam, b)].get("mesh", {})
            # the zero-partial-sum invariant, rendered per mesh row: 0 for
            # cascade is the paper's claim holding as a measurement
            ar = m.get("partial_sum_allreduces", "—")
            mesh_tok = m.get("tokens_per_s", "—")
            if m:
                mesh_tok = f"{mesh_tok} ({m.get('tp_policy', '?')})"
            ps_acc = "—"
            if ps:
                ps_acc = (f"{ps.get('accepted_per_step', '—')} "
                          f"(T={ps.get('temperature', '?')}, "
                          f"V={ps.get('vocab', '?')})")
            print(f"| {fam} | {b} | {s.get('tokens_per_s','—')} "
                  f"| {k.get('tokens_per_s','—')} "
                  f"| {k.get('speedup_vs_slotwise','—')}x | {k.get('step_ms_p99','—')} "
                  f"| {p.get('tokens_per_s','—')} | {p.get('accepted_per_step','—')} "
                  f"| {p.get('speedup_vs_batched','—')}x "
                  f"| {ps.get('tokens_per_s','—')} | {ps_acc} "
                  f"| {mesh_tok} | {ar} |")

    # ROADMAP wiring: measured decode tokens/s (CPU smoke models, serving
    # bench) next to the TPU weight-streaming bound from the roofline decode
    # cells (Table 9/10 projection). The pairing is deliberately labelled —
    # smoke measurement vs production projection — so the table reads as
    # "what we measured" and "what the paper's balance permits", per family.
    roof = next((d for d in (opt, faith, base) if d), None)
    bound_rows = [r for r in (roof or {}).values()
                  if r.get("status") == "ok" and "decode_bound_tokens_per_s" in r]
    if bound_rows and rows:
        fam_of = {}
        try:
            import sys
            sys.path.insert(0, "src")
            from repro.models import registry as _reg
            for alias in _reg.ALIASES:
                fam_of[alias] = _reg.get_config(alias).family
        except Exception:
            pass
        measured = {}
        for r in rows:
            if r.get("mode") == "batched" and isinstance(r.get("max_batch"), int):
                f = r.get("family")
                if f and r["max_batch"] >= measured.get(f, (0, 0))[0]:
                    measured[f] = (r["max_batch"], r["tokens_per_s"])
        print("\n## Decode: measured vs weight-streaming bound\n")
        print("bound = global_batch / (per-device state bytes / HBM bw) — the "
              "Table 9/10 weight-streaming ceiling on the production mesh; "
              "measured = CPU smoke-scale serving bench (largest batch).\n")
        print("| arch | shape | family | bound tok/s (TPU projection) "
              "| weight-stream GB/dev | measured tok/s (CPU smoke) |")
        print("|" + "---|" * 6)
        for r in sorted(bound_rows, key=lambda x: (x.get("arch", "?"),
                                                   x.get("shape", "?"))):
            fam = fam_of.get(r.get("arch", "?"), "?")
            # config families -> serving-bench families (dense GQA/MHA and
            # the modality stubs all decode through the transformer engine)
            fam = {"hybrid": "griffin", "dense": "transformer",
                   "audio": "transformer", "vlm": "transformer"}.get(fam, fam)
            mb, mt = measured.get(fam, (None, "—"))
            gb = (r.get("weight_stream_bytes_per_device") or 0) / 1e9
            mcell = f"{mt} (b={mb})" if mb else "—"
            print(f"| {r.get('arch', '?')} | {r.get('shape', '?')} | {fam} "
                  f"| {r['decode_bound_tokens_per_s']} | {gb:.2f} | {mcell} |")

    for line in fused_lines(rows):
        print(line)

    trows = traffic_rows()
    for line in traffic_lines(trows):
        print(line)

    for line in prefix_lines(rows, trows):
        print(line)

    for line in audit_lines(audit_data()):
        print(line)

    # CASCADE invariant check: forward graphs with zero all-reduce bytes
    print("\n## CASCADE zero-partial-sum invariant (faithful preset)\n")
    viol = []
    for key, f in faith.items():
        if f.get("status") != "ok" or key[1] == "train_4k":
            continue
        ar = f["collectives_corrected"]["all-reduce"]["bytes"]
        if ar > 1e9:
            viol.append((key, ar))
    if viol:
        print("all-reduce >1GB found in (MoE dispatch reductions — see DESIGN.md):")
        for (a, s), ar in viol:
            print(f"- {a} x {s}: {ar/1e9:.1f} GB")
    else:
        print("No serving-graph all-reduce above 1 GB/device anywhere.")


if __name__ == "__main__":
    main()

"""Import shim: the HLO parser moved to ``repro.analysis.hlo`` so the
serving-contract auditor (``repro.analysis.contract``) can use it without
depending on benchmarks/. Existing callers keep importing from here.
"""
from repro.analysis.hlo import (  # noqa: F401
    _COLLECTIVES,
    _DTYPE_BYTES,
    _multiplicities,
    Computation,
    Instr,
    analyze,
    collective_budget,
    donation_aliases,
    dtype_audit,
    host_transfers,
    parse_computations,
    partial_sum_allreduces,
)

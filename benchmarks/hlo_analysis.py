"""Structural HLO analysis for the roofline (§Roofline).

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
empirically), which would undercount scanned-layer models by n_layers. This
module parses ``compiled.as_text()`` into a computation call graph, reads
``known_trip_count`` off every while op, and propagates multiplicities to:

* dot FLOPs (2 * prod(out_shape) * prod(contracted lhs dims)), and
* collective bytes (output tensor bytes per op, per device),

giving loop-corrected per-device totals. Convolution/elementwise FLOPs are
ignored (dots dominate every assigned arch).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f4e2m1fn": 1, "s4": 1, "u4": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}]+))\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\)|[\w\[\],\{\}]+))")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        if dt in _DTYPE_BYTES:
            total += math.prod(dims) * _DTYPE_BYTES[dt] if dims else _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    instrs: List[Instr]


def parse_computations(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        m = _COMP_RE.match(raw)
        if m:
            is_entry, name, params_str, _ = m.groups()
            params = {}
            for pm in _PARAM_RE.finditer(params_str):
                params[pm.group(1)] = pm.group(2)
            cur = Computation(name=name, params=params, instrs=[])
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        im = _INSTR_RE.match(raw)
        if im:
            cur.instrs.append(Instr(*im.groups()))
    return comps, entry


def _multiplicities(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """computation name -> times executed per program run."""
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, stack=()):
        if name not in comps or name in stack:
            return
        mult[name] += m
        for ins in comps[name].instrs:
            trip = 1.0
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.rest)
                trip = float(tm.group(1)) if tm else 1.0
            refs = _CALL_RE.findall(ins.rest)
            for i, (kw_match, target) in enumerate(
                    [(k.group(0), k.group(1)) for k in _CALL_RE.finditer(ins.rest)]):
                child_m = m
                if kw_match.startswith("body="):
                    child_m = m * trip
                elif kw_match.startswith("condition="):
                    child_m = m * (trip + 1)
                visit(target, child_m, stack + (name,))

    visit(entry, 1.0)
    return dict(mult)


def analyze(text: str) -> dict:
    """Loop-corrected per-device dot FLOPs + collective bytes."""
    comps, entry = parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = _multiplicities(comps, entry)

    dot_flops = 0.0
    dot_flops_uncorrected = 0.0
    coll = {c: {"count": 0.0, "bytes": 0.0, "bytes_uncorrected": 0.0} for c in _COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        # symbol table: instruction/param name -> type string
        sym: Dict[str, str] = dict(comp.params)
        for ins in comp.instrs:
            sym[ins.name] = ins.type_str
        for ins in comp.instrs:
            if ins.op == "dot":
                out_dims = _shape_dims(ins.type_str)
                out_elems = math.prod(out_dims[0][1]) if out_dims and out_dims[0][1] else 1
                ops = _OPERANDS_RE.findall(ins.rest)
                cd = _CDIMS_RE.search(ins.rest)
                k = 1
                if ops and cd is not None and ops[0] in sym:
                    lhs_dims = _shape_dims(sym[ops[0]])
                    if lhs_dims and lhs_dims[0][1]:
                        for d in cd.group(1).split(","):
                            if d:
                                k *= lhs_dims[0][1][int(d)]
                f = 2.0 * out_elems * k
                dot_flops += m * f
                dot_flops_uncorrected += f
            else:
                base = None
                for c in _COLLECTIVES:
                    if ins.op == c or ins.op == c + "-start":
                        base = c
                        break
                if base is not None:
                    b = _type_bytes(ins.type_str)
                    coll[base]["count"] += m
                    coll[base]["bytes"] += m * b
                    coll[base]["bytes_uncorrected"] += b

    total_coll = sum(v["bytes"] for v in coll.values())
    return {
        "dot_flops": dot_flops,
        "dot_flops_uncorrected": dot_flops_uncorrected,
        "collectives": coll,
        "collective_bytes": total_coll,
    }


def partial_sum_allreduces(text: str) -> dict:
    """Count all-reduce ops whose combiner is an ADD — partial-sum traffic,
    the quantity CASCADE abolishes (paper Sections 2.2, 13.5).

    An all-reduce's reduction computation is named by ``to_apply=``; a
    combiner CONTAINING an ``add`` accumulates partial products (max/min/or
    combiners — argmax lowerings, mask folds — are not partial sums and are
    ignored). Containment rather than root-op equality matters for variadic
    all-reduces (XLA's combiner pass merges several into one op whose
    combiner ROOTs a ``tuple`` of adds), and the async ``-start`` forms of
    both all-reduce and reduce-scatter are counted — a gate must
    over-approximate, never false-negative. Returns
    ``{"count", "bytes", "ops": [(name, bytes), ...]}`` over EVERY
    computation in the module, loop bodies included — the serving assertion
    is "zero partial-sum all-reduce anywhere in the decode step", so no
    multiplicity weighting is needed.
    """
    comps, _ = parse_computations(text)
    out = {"count": 0, "bytes": 0, "ops": []}
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op not in ("all-reduce", "all-reduce-start",
                              "reduce-scatter", "reduce-scatter-start"):
                continue
            target = None
            for kw in _CALL_RE.finditer(ins.rest):
                if kw.group(0).startswith("to_apply="):
                    target = kw.group(1)
                    break
            combiner_adds = (target in comps and
                             any(i.op == "add" for i in comps[target].instrs))
            if combiner_adds:
                b = _type_bytes(ins.type_str)
                out["count"] += 1
                out["bytes"] += b
                out["ops"].append((f"{comp.name}/{ins.name}", b))
    return out

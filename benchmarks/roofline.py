"""§Roofline: three-term roofline per (arch x shape) on the single-pod mesh.

    compute term    = dot_FLOPs_per_device / peak_FLOP/s_per_chip
    memory term     = state_bytes_per_device / HBM_bw          (see note)
    collective term = collective_bytes_per_device / link_bw

Sources: dot FLOPs and collective bytes come from the **loop-corrected**
structural HLO analysis (hlo_analysis.py) — compiled.cost_analysis() counts
while bodies once and would undercount scanned-layer models by n_layers.
Memory bytes use argument+output sizes from memory_analysis (exact,
loop-independent): the HBM traffic of streaming weights/optimizer state/KV
cache once per step — the roofline minimum that Table 10 of the paper
balances. cost_analysis' 'bytes accessed' is reported alongside as
``hlo_bytes_1iter`` (uncorrected).

Hardware constants (TPU v5e-class, per task spec): 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS=512 devices FIRST)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def roofline_cell(arch_id: str, shape_name: str, mesh=None, tp_policy: str = "cascade",
                  ccfg=None, act_policy: str = "cascade", dp_shard: str = "none",
                  full_dp: bool = False, remat_policy: str = "dots",
                  microbatches: int = 1, moe_ep: bool = False) -> dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh
    from repro.configs import base as cfgbase
    from repro.core import flops as F
    from repro.models import registry
    from benchmarks import hlo_analysis

    mesh = mesh or make_production_mesh(multi_pod=False)
    chips = 1
    for v in mesh.shape.values():
        chips *= v

    cfg = registry.get_config(arch_id)
    shape = cfgbase.SHAPES[shape_name]
    if not cfgbase.shape_applicable(cfg, shape):
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention"}

    rec = dryrun.lower_cell(arch_id, shape_name, mesh, ccfg=ccfg,
                            tp_policy=tp_policy, verbose=False,
                            return_compiled=True, act_policy=act_policy,
                            dp_shard=dp_shard, full_dp=full_dp,
                            remat_policy=remat_policy, microbatches=microbatches,
                            moe_ep=moe_ep)
    compiled = rec.pop("_compiled")
    h = hlo_analysis.analyze(compiled.as_text())

    flops_dev = h["dot_flops"]
    coll_dev = h["collective_bytes"]
    mem = rec["memory"]
    state_bytes = (mem["argument_bytes"] or 0) + (mem["output_bytes"] or 0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = state_bytes / HBM_BW
    t_collective = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    bound_time = terms[dominant]
    roofline_fraction = t_compute / max(bound_time, 1e-30)

    mf = F.step_flops(cfg, shape)
    hlo_total_flops = flops_dev * chips
    useful_ratio = mf["total"] / max(hlo_total_flops, 1e-30)

    suggestions = {
        "compute": "compute-bound: raise MXU utilization (bigger per-chip tiles, "
                   "bf16 paths, fewer fp32 casts) or shrink redundant recompute (remat policy)",
        "memory": "memory-bound: cut state traffic — FP4/FP8 weights & KV cache, "
                  "ZeRO-shard optimizer moments over data, larger batch for weight reuse",
        "collective": "collective-bound: reshard to kill partial-sum all-reduces "
                      "(CASCADE policy), overlap gathers with compute, reduce-scatter "
                      "gradient sync, shrink activation gathers via sequence parallelism",
    }

    if shape.kind == "decode":
        # the Table 9/10 weight-streaming balance: a decode step cannot beat
        # streaming the per-device state (FP4 weights + KV) from HBM once,
        # so tokens/s <= global_batch / memory-term. benchmarks/report.py
        # renders this bound next to the MEASURED decode throughput from
        # results/bench_serving.json (the ROADMAP measured-vs-projection
        # wiring; the measurement is CPU smoke-scale, the bound is the TPU
        # projection — the column pairs them, it does not equate them).
        rec["decode_bound_tokens_per_s"] = round(
            shape.global_batch / max(t_memory, 1e-30), 2)
        rec["weight_stream_bytes_per_device"] = state_bytes

    rec.update({
        "chips": chips,
        "dot_flops_per_device": flops_dev,
        "dot_flops_1iter": h["dot_flops_uncorrected"],
        "collective_bytes_per_device": coll_dev,
        "collectives_corrected": h["collectives"],
        "state_bytes_per_device": state_bytes,
        "hlo_bytes_1iter": rec.get("bytes_accessed_per_device"),
        "terms_s": {k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "step_time_bound_s": round(bound_time, 6),
        "roofline_fraction": round(roofline_fraction, 4),
        "model_flops": mf["total"],
        "model_flops_breakdown": {k: v for k, v in mf.items() if k != "total"},
        "useful_flops_ratio": round(useful_ratio, 4),
        "suggestion": suggestions[dominant],
    })
    return rec


PRESETS = {
    # paper-faithful: CASCADE discipline (zero fwd partial-sum all-reduce),
    # bf16 KV, plain DP+TP layout
    "faithful": dict(act_policy="cascade", dp_shard="none", full_dp=False,
                     remat_policy="dots", kv_fp8=False, moe_ep=False),
    # beyond-paper winners per step kind (see EXPERIMENTS.md §Perf)
    "optimized": "per_kind",
}
OPT_BY_KIND = {
    "train": dict(act_policy="fulldp", dp_shard="fsdp", full_dp=True,
                  remat_policy="none", kv_fp8=False, moe_ep=False),
    "prefill": dict(act_policy="seqpar", dp_shard="none", full_dp=False,
                    remat_policy="dots", kv_fp8=False, moe_ep=False),
    "decode": dict(act_policy="cascade", dp_shard="none", full_dp=False,
                   remat_policy="dots", kv_fp8=True, moe_ep=False),
}
# MoE: expert parallelism lives on the model axis — full-DP over model
# conflicts with EP (measured: deepseek train 0.188 -> 0.019 under full_dp),
# and ZeRO moment sharding lands on the scanned layer dim (0.188 -> 0.055).
# The faithful EP config is the best known for MoE train.
OPT_MOE_TRAIN = dict(act_policy="cascade", dp_shard="none", full_dp=False,
                     remat_policy="dots", kv_fp8=False, moe_ep=True)
# shard_map EP dispatch (models/moe_shardmap.py) for every MoE step kind
OPT_MOE = {
    "train": OPT_MOE_TRAIN,
    "prefill": dict(act_policy="cascade", dp_shard="none", full_dp=False,
                    remat_policy="dots", kv_fp8=False, moe_ep=True),
    "decode": dict(act_policy="cascade", dp_shard="none", full_dp=False,
                   remat_policy="dots", kv_fp8=True, moe_ep=True),
}


def _cell_with_preset(arch, shape, preset):
    import jax.numpy as jnp
    from repro.configs import base as cfgbase
    from repro.core.cascade import CascadeConfig
    from repro.models import registry as _reg
    kind = cfgbase.SHAPES[shape].kind
    kw = dict(OPT_BY_KIND[kind]) if preset == "optimized" else dict(PRESETS["faithful"])
    if preset == "optimized" and _reg.get_config(arch).family == "moe":
        kw = dict(OPT_MOE[kind])
    kv_fp8 = kw.pop("kv_fp8")
    kw.setdefault("moe_ep", False)
    ccfg = CascadeConfig(mode="train" if kind == "train" else "serve_fp4",
                         kv_dtype=jnp.float8_e4m3fn if kv_fp8 else jnp.bfloat16)
    return roofline_cell(arch, shape, ccfg=ccfg, **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--preset", default=None, choices=[None, "faithful", "optimized"])
    ap.add_argument("--tp-policy", default="cascade")
    ap.add_argument("--out", default="results/roofline_baseline.json")
    args = ap.parse_args()

    from repro.models import registry
    from repro.configs import base as cfgbase

    archs = [args.arch] if args.arch else list(registry.ALIASES.keys())
    shapes = [args.shape] if args.shape else list(cfgbase.SHAPES.keys())

    records = []
    for arch in archs:
        for shape in shapes:
            t0 = time.time()
            try:
                if args.preset:
                    rec = _cell_with_preset(arch, shape, args.preset)
                else:
                    rec = roofline_cell(arch, shape, tp_policy=args.tp_policy)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "status": "FAILED",
                       "error": f"{type(e).__name__}: {e}"}
            rec["wall_s"] = round(time.time() - t0, 1)
            print(json.dumps({k: rec.get(k) for k in
                              ("arch", "shape", "status", "dominant",
                               "roofline_fraction", "terms_s", "useful_flops_ratio")},
                             default=str), flush=True)
            records.append(rec)

    import os
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1, default=str)


if __name__ == "__main__":
    main()

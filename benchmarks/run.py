"""Benchmark harness entry point: one function per paper table.

Prints ``name,us_per_call,derived`` CSV. The paper has no measured tables —
it is a design study — so each benchmark reproduces the table's analytic
derivation and asserts agreement with the published numbers (the faithful-
reproduction validation), timing the derivation itself.

Run: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import time


def _timed(fn, *args, repeat: int = 5, **kw):
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return us, out


def main() -> None:
    from benchmarks import paper_tables as T

    rows = []

    us, out = _timed(T.bench_table6)
    rows.append(("table6_cascade_schedule", us, out))

    us, out = _timed(T.bench_table9_10)
    rows.append(("table9_10_llama405b_balance", us, out))

    us, out = _timed(T.bench_table1_20)
    rows.append(("table1_20_rack_comparison", us, out))

    us, out = _timed(T.bench_pe_model)
    rows.append(("table2_4_5_pe_model", us, out))

    # CASCADE kernel micro-benchmark (interpret mode on CPU — correctness
    # path; wall time is NOT a TPU estimate, the roofline handles perf)
    def kernel_call():
        import jax
        from repro.core import quant
        from repro.kernels import ops
        w = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.1
        packed, scales = quant.quantize_weight(w, group_size=64)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 256))
        out = ops.cascade_matmul(x, packed, scales, block_m=64, block_n=64,
                                 block_k=64, interpret=True)
        return float(out.sum())

    us, out = _timed(kernel_call, repeat=3)
    rows.append(("cascade_matmul_kernel_interpret", us, {"checksum": round(out, 3)}))

    # paper's weight-reuse rule applied to our TPU constants
    def balance():
        from benchmarks.paper_tables import balanced_batch_size
        return {"tpu_v5e_fp4_decode_B*": round(balanced_batch_size(197e12, 819e9), 1),
                "zettalith_fp4_decode_B*": round(balanced_batch_size(
                    T.ZETTALITH_PEAK_SPARSE, T.ZETTALITH_HBM_BW), 1)}

    us, out = _timed(balance)
    rows.append(("weight_reuse_balance_tpu", us, out))

    # roofline sweep summaries (if the sweeps have been run)
    import os, statistics
    for preset in ("baseline", "faithful", "optimized"):
        path = f"results/roofline_{preset}.json"
        if os.path.exists(path):
            recs = json.load(open(path))
            ok = [r for r in recs if r.get("status") == "ok"]
            fr = [r["roofline_fraction"] for r in ok]
            rows.append((f"roofline_{preset}", 0.0, {
                "cells_ok": len(ok),
                "skipped": sum(r.get("status") == "skipped" for r in recs),
                "failed": sum(r.get("status") == "FAILED" for r in recs),
                "median_fraction": round(statistics.median(fr), 4) if fr else None,
                "best_fraction": round(max(fr), 4) if fr else None,
            }))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{json.dumps(derived, default=str)}")


if __name__ == "__main__":
    main()

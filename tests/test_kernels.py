"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (16, 32, 16, 16, 16, 32),
    (48, 64, 32, 16, 16, 32),      # m padded to block
    (128, 256, 128, 64, 64, 64),   # multi-block all dims
    (8, 128, 64, 8, 32, 32),       # K-grid accumulation
])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_cascade_matmul_vs_ref(m, k, n, bm, bn, bk, xdtype):
    key = jax.random.PRNGKey(m * 1000 + k + n)
    w = jax.random.normal(key, (k, n)) * 0.1
    packed, scales = quant.quantize_weight(w, group_size=bk)
    x = (jax.random.normal(jax.random.PRNGKey(1), (m, k)) * 0.5).astype(xdtype)
    bias = jax.random.normal(jax.random.PRNGKey(2), (n,))
    out_k = ops.cascade_matmul(x, packed, scales, bias,
                               block_m=bm, block_n=bn, block_k=bk,
                               interpret=True, exact_dequant=False)
    out_r = ops.cascade_matmul_ref(x, packed, scales, bias)
    # the kernel feeds the MXU in bf16 BY DESIGN (TPU path); XLA-CPU's bf16
    # dot is nondeterministically exact-or-rounded, so tolerances are bf16-scale
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=3e-2, rtol=3e-2)


def test_cascade_matmul_batched_leading_dims():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 32)) * 0.1
    packed, scales = quant.quantize_weight(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 64))
    out = ops.cascade_matmul(x, packed, scales, block_m=8, block_n=32, block_k=64,
                             interpret=True, exact_dequant=False)
    ref = ops.cascade_matmul_ref(x.reshape(-1, 64), packed, scales).reshape(2, 5, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-2, rtol=3e-2)


def test_cascade_matmul_groupwise_scales():
    k, n = 128, 32
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n)) * 0.1
    packed, scales = quant.quantize_weight(w, group_size=32)
    assert scales.shape == (4, n)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, k)) * 0.5
    out = ops.cascade_matmul(x, packed, scales, block_m=16, block_n=32, block_k=32,
                             interpret=True, exact_dequant=False)
    ref = ops.cascade_matmul_ref(x, packed, scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("b,hq,hkv,s,d,bq,bk", [
    (1, 2, 2, 128, 32, 64, 64),
    (2, 4, 2, 256, 64, 64, 128),   # GQA group=2
    (1, 8, 1, 128, 64, 128, 32),   # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(b, hq, hkv, s, d, bq, bk, causal):
    keys = jax.random.split(jax.random.PRNGKey(b * 7 + s), 3)
    q = jax.random.normal(keys[0], (b, hq, s, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, hkv, s, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    ref = ops.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(keys[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(keys[1], (1, 4, 128, 64), jnp.bfloat16)
    v = jax.random.normal(keys[2], (1, 4, 128, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    ref = ops.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref, dtype=np.float32), atol=3e-2, rtol=3e-2)


def test_ssd_scan_ref_matches_chunked_model_impl():
    """The sequential SSD oracle must match the chunked dual form used by the
    Mamba-2 model (arXiv:2405.21060 establishes their equivalence)."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, g, n = 2, 64, 4, 8, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(keys[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    B = jax.random.normal(keys[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(keys[4], (b, s, g, n)) * 0.3
    D = jnp.ones((h,))
    y_chunk, _ = ssd_chunked(x, dt, A, B, C, D, chunk=16)
    y_ref = jax.vmap(lambda xx, dd, bb, cc: ops.ssd_scan_ref(xx, dd, A, bb, cc, D))(x, dt, B, C)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref), atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("bh,s,p,n,chunk", [(2, 64, 8, 4, 16), (4, 128, 16, 8, 32),
                                            (1, 32, 32, 16, 32)])
def test_ssd_scan_kernel_vs_ref(bh, s, p, n, chunk):
    keys = jax.random.split(jax.random.PRNGKey(bh * 31 + s), 5)
    x = jax.random.normal(keys[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (bh, s)))
    A = -jnp.exp(jax.random.normal(keys[2], (bh,)) * 0.3)
    B = jax.random.normal(keys[3], (bh, s, n)) * 0.3
    C = jax.random.normal(keys[4], (bh, s, n)) * 0.3
    D = jnp.ones((bh,))
    out = ops.ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    refout = jax.vmap(lambda xx, dd, aa, bb, cc, ddk: ops.ssd_scan_ref(
        xx[:, None, :], dd[:, None], aa[None], bb[:, None, :], cc[:, None, :],
        ddk[None])[:, 0, :])(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refout), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# exact interpret-mode paths: the fused serving contract is BIT-parity with
# the jnp serve path, not allclose
# ---------------------------------------------------------------------------

def _jnp_serve_matmul(x, packed, scales, bias, out_dtype=jnp.float32):
    """Replicates cascade.linear_apply's serve_fp4 XLA branch (the oracle
    the exact kernel must match bit-for-bit)."""
    w = quant.dequantize_weight(packed, scales, out_dtype)
    if w.shape[0] == x.shape[-1] + 1:   # odd-K pad-to-pack zero row
        x = jnp.pad(x, ((0, 0), (0, 1)))
    out = jnp.dot(x.astype(out_dtype), w, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias
    return out.astype(out_dtype)


@pytest.mark.parametrize("m,k,n,group,use_bias", [
    (4, 256, 512, 0, True),
    (7, 96, 130, 0, False),
    (12, 128, 64, 32, True),    # grouped scales
    (3, 255, 66, 0, True),      # odd K: quantize_weight pad-to-pack
])
def test_cascade_matmul_exact_bit_parity(m, k, n, group, use_bias):
    key = jax.random.PRNGKey(m * 131 + k)
    w = jax.random.normal(key, (k, n)) * 0.1
    packed, scales = quant.quantize_weight(w, group_size=group)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k)) * 0.5
    bias = jax.random.normal(jax.random.PRNGKey(2), (n,)) if use_bias else None
    out = ops.cascade_matmul(x, packed, scales, bias, interpret=True)
    ref = jax.jit(lambda *a: _jnp_serve_matmul(*a, bias))(x, packed, scales)
    assert bool(jnp.all(out == ref)), float(jnp.max(jnp.abs(out - ref)))


def test_cascade_matmul_exact_requires_interpret():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16)) * 0.1
    packed, scales = quant.quantize_weight(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    with pytest.raises(AssertionError):
        ops.cascade_matmul(x, packed, scales, interpret=False,
                           exact_dequant=True)


def _decode_attn_inputs(b, hq, hkv, t, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, hkv, d), jnp.float32)
    valid = (jax.random.uniform(ks[3], (b, t)) > 0.3).astype(jnp.int32)
    return q, k, v, valid.at[:, 0].set(1)   # >= 1 live slot per row


@pytest.mark.parametrize("b,hq,hkv,t,d", [
    (3, 8, 2, 17, 32),    # GQA, ragged T
    (1, 6, 3, 5, 8),
    (2, 4, 4, 64, 16),    # MHA (group=1) — the einsum-lowering trap shape
    (1, 2, 1, 1, 4),      # single cache slot
])
def test_decode_attention_exact_bit_parity(b, hq, hkv, t, d):
    q, k, v, valid = _decode_attn_inputs(b, hq, hkv, t, d, seed=b * 7 + t)
    out = ops.decode_attention(q, k, v, valid)          # interpret => exact
    ref = jax.jit(ops.decode_attention_ref)(q, k, v, valid)
    assert bool(jnp.all(out == ref)), float(jnp.max(jnp.abs(out - ref)))


@pytest.mark.parametrize("b,hq,hkv,t,d,bt", [
    (3, 8, 2, 100, 32, 32),   # T padded to block multiple
    (2, 4, 4, 64, 16, 16),
    (1, 6, 3, 7, 8, 4),
])
def test_decode_attention_streaming_vs_ref(b, hq, hkv, t, d, bt):
    from repro.kernels.flash_attention import decode_attention_pallas
    q, k, v, valid = _decode_attn_inputs(b, hq, hkv, t, d, seed=t)
    out = decode_attention_pallas(q, k, v, valid, block_t=bt,
                                  exact=False, interpret=True)
    ref = ops.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_streaming_fully_masked_block():
    """A trailing cache block with zero live slots must not pollute the
    running softmax denominator (exp(0)=1 guard in the kernel)."""
    from repro.kernels.flash_attention import decode_attention_pallas
    q, k, v, valid = _decode_attn_inputs(2, 4, 2, 64, 16, seed=5)
    valid = valid.at[:, 32:].set(0)        # second 32-block fully dead
    out = decode_attention_pallas(q, k, v, valid, block_t=32,
                                  exact=False, interpret=True)
    ref = ops.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD decode through the scan kernel (fused serving dispatch)
# ---------------------------------------------------------------------------

def _ssd_decode_inputs(b, h, p, g, n, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(keys[0], (b, 1, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, 1, h)))
    A = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    B = jax.random.normal(keys[3], (b, 1, g, n)) * 0.3
    C = jax.random.normal(keys[4], (b, 1, g, n)) * 0.3
    D = jnp.ones((h,))
    state = jax.random.normal(keys[5], (b, h, p, n)) * 0.2
    return x, dt, A, B, C, D, state


@pytest.mark.parametrize("b,h,p,g,n", [(2, 4, 8, 2, 16), (1, 2, 16, 1, 8),
                                       (3, 6, 4, 3, 4)])
def test_ssd_decode_matches_jitted_step(b, h, p, g, n):
    """ops.ssd_decode (the scan kernel at s = chunk = 1 with carried slot
    states) vs the JITTED jnp decode step — jit vs jit, because XLA
    contracts a*b+c into FMA under jit but not in eager op-by-op dispatch,
    so the eager form is the one with different numerics, not the kernel.
    Agreement is near-machine-epsilon here (XLA's per-shape fusion choices
    keep strict bitwise from being a universal guarantee); the serving
    contract — TOKEN-exact fused-vs-jnp streams — is gated end-to-end in
    tests/test_fused.py."""
    from repro.models.ssm import ssd_decode_step

    x, dt, A, B, C, D, state = _ssd_decode_inputs(b, h, p, g, n, seed=h + n)
    ref_fn = jax.jit(ssd_decode_step)
    for _ in range(4):                       # carry the state a few steps
        y, ns = ops.ssd_decode(x, dt, A, B, C, D, state)
        y_ref, ns_ref = ref_fn(x, dt, A, B, C, D, state)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-6, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ns), np.asarray(ns_ref),
                                   atol=1e-6, rtol=1e-6)
        state = ns


def test_ssd_scan_carry_splits_at_chunk_boundary():
    """ssd_scan_pallas's carry extension: running the second half with the
    first half's returned state is bit-identical to the unsplit run (the
    kernel is sequential over chunks, so a chunk-aligned split changes no
    reduction order), and a zero initial state reproduces the original
    cold-start path exactly."""
    from repro.kernels.ssd_scan import ssd_scan_pallas

    bh, s, p, n, chunk = 3, 64, 8, 4, 16
    keys = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(keys[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (bh, s)))
    A = -jnp.exp(jax.random.normal(keys[2], (bh,)) * 0.3)
    B = jax.random.normal(keys[3], (bh, s, n)) * 0.3
    C = jax.random.normal(keys[4], (bh, s, n)) * 0.3
    D = jnp.ones((bh,))
    y_cold = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    zeros = jnp.zeros((bh, p, n), jnp.float32)
    y_z, fin = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=True,
                               initial_state=zeros, return_final_state=True)
    assert bool(jnp.all(y_z == y_cold))
    half = s // 2
    y1, s1 = ssd_scan_pallas(x[:, :half], dt[:, :half], A, B[:, :half],
                             C[:, :half], D, chunk=chunk, interpret=True,
                             initial_state=zeros, return_final_state=True)
    y2, s2 = ssd_scan_pallas(x[:, half:], dt[:, half:], A, B[:, half:],
                             C[:, half:], D, chunk=chunk, interpret=True,
                             initial_state=s1, return_final_state=True)
    assert bool(jnp.all(jnp.concatenate([y1, y2], axis=1) == y_cold))
    assert bool(jnp.all(s2 == fin))

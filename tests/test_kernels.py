"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (16, 32, 16, 16, 16, 32),
    (48, 64, 32, 16, 16, 32),      # m padded to block
    (128, 256, 128, 64, 64, 64),   # multi-block all dims
    (8, 128, 64, 8, 32, 32),       # K-grid accumulation
])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_cascade_matmul_vs_ref(m, k, n, bm, bn, bk, xdtype):
    key = jax.random.PRNGKey(m * 1000 + k + n)
    w = jax.random.normal(key, (k, n)) * 0.1
    packed, scales = quant.quantize_weight(w, group_size=bk)
    x = (jax.random.normal(jax.random.PRNGKey(1), (m, k)) * 0.5).astype(xdtype)
    bias = jax.random.normal(jax.random.PRNGKey(2), (n,))
    out_k = ops.cascade_matmul(x, packed, scales, bias,
                               block_m=bm, block_n=bn, block_k=bk, interpret=True)
    out_r = ops.cascade_matmul_ref(x, packed, scales, bias)
    # the kernel feeds the MXU in bf16 BY DESIGN (TPU path); XLA-CPU's bf16
    # dot is nondeterministically exact-or-rounded, so tolerances are bf16-scale
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=3e-2, rtol=3e-2)


def test_cascade_matmul_batched_leading_dims():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 32)) * 0.1
    packed, scales = quant.quantize_weight(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 64))
    out = ops.cascade_matmul(x, packed, scales, block_m=8, block_n=32, block_k=64, interpret=True)
    ref = ops.cascade_matmul_ref(x.reshape(-1, 64), packed, scales).reshape(2, 5, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-2, rtol=3e-2)


def test_cascade_matmul_groupwise_scales():
    k, n = 128, 32
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n)) * 0.1
    packed, scales = quant.quantize_weight(w, group_size=32)
    assert scales.shape == (4, n)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, k)) * 0.5
    out = ops.cascade_matmul(x, packed, scales, block_m=16, block_n=32, block_k=32, interpret=True)
    ref = ops.cascade_matmul_ref(x, packed, scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("b,hq,hkv,s,d,bq,bk", [
    (1, 2, 2, 128, 32, 64, 64),
    (2, 4, 2, 256, 64, 64, 128),   # GQA group=2
    (1, 8, 1, 128, 64, 128, 32),   # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(b, hq, hkv, s, d, bq, bk, causal):
    keys = jax.random.split(jax.random.PRNGKey(b * 7 + s), 3)
    q = jax.random.normal(keys[0], (b, hq, s, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, hkv, s, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    ref = ops.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(keys[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(keys[1], (1, 4, 128, 64), jnp.bfloat16)
    v = jax.random.normal(keys[2], (1, 4, 128, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    ref = ops.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref, dtype=np.float32), atol=3e-2, rtol=3e-2)


def test_ssd_scan_ref_matches_chunked_model_impl():
    """The sequential SSD oracle must match the chunked dual form used by the
    Mamba-2 model (arXiv:2405.21060 establishes their equivalence)."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, g, n = 2, 64, 4, 8, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(keys[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    B = jax.random.normal(keys[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(keys[4], (b, s, g, n)) * 0.3
    D = jnp.ones((h,))
    y_chunk, _ = ssd_chunked(x, dt, A, B, C, D, chunk=16)
    y_ref = jax.vmap(lambda xx, dd, bb, cc: ops.ssd_scan_ref(xx, dd, A, bb, cc, D))(x, dt, B, C)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref), atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("bh,s,p,n,chunk", [(2, 64, 8, 4, 16), (4, 128, 16, 8, 32),
                                            (1, 32, 32, 16, 32)])
def test_ssd_scan_kernel_vs_ref(bh, s, p, n, chunk):
    keys = jax.random.split(jax.random.PRNGKey(bh * 31 + s), 5)
    x = jax.random.normal(keys[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (bh, s)))
    A = -jnp.exp(jax.random.normal(keys[2], (bh,)) * 0.3)
    B = jax.random.normal(keys[3], (bh, s, n)) * 0.3
    C = jax.random.normal(keys[4], (bh, s, n)) * 0.3
    D = jnp.ones((bh,))
    out = ops.ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    refout = jax.vmap(lambda xx, dd, aa, bb, cc, ddk: ops.ssd_scan_ref(
        xx[:, None, :], dd[:, None], aa[None], bb[:, None, :], cc[:, None, :],
        ddk[None])[:, 0, :])(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refout), atol=2e-4, rtol=2e-4)

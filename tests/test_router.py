"""SLO-aware router over live traffic: determinism, failover, shedding.

The acceptance contracts of the live-traffic harness:

* two runs of the same seeded trace under the VirtualClock produce
  IDENTICAL per-request TTFT/inter-token records (latency is data, not
  noise, in tests);
* a mid-trace ``kill_replica`` drains with ZERO lost tokens and
  token-exact re-routed streams (greedy decode is schedule-independent,
  so the killed run must emit exactly the unkilled run's tokens);
* admission-deadline shedding is honestly accounted: offered =
  finished + shed + rejected, and shed SLO-stamped requests count as
  attainment MISSES;
* the headroom gate keeps engine queues bounded so waiting work stays in
  the router where the deadline check can reach it.
"""
import jax
import numpy as np
import pytest

from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.elastic import ReplicaSet
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.router import SLORouter
from repro.serve.traffic import TrafficConfig, VirtualClock, poisson_trace

import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def tiny_model():
    cfg, model = registry.load("codeqwen1.5-7b", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


def _run(tiny_model, tcfg, kills=(), replicas=2, max_batch=2,
         step_cost=0.01, slow_replica=None):
    cfg, model, params = tiny_model
    clk = VirtualClock()
    scfg = ServeConfig(max_batch=max_batch, max_len=64, batched=True,
                       prefill_chunk=8)
    engines = [ServeEngine(model, params, CCFG, scfg, clock=clk)
               for _ in range(replicas)]
    cost = (lambda i: step_cost * (100.0 if i == slow_replica else 1.0))
    rs = ReplicaSet(engines, clock=clk, step_cost=cost)
    router = SLORouter(rs)
    recs = router.run_trace(poisson_trace(tcfg), kills=kills)
    return recs, router


def _records(recs):
    return [(r.uid, r.created_at, r.first_token_at, tuple(r.token_times),
             tuple(r.tokens_out)) for r in recs]


TCFG = TrafficConfig(rate_rps=25.0, n_requests=16, vocab=512,
                     prompt_lens=((4, 10),), output_lens=((8, 14),),
                     slo_ttft_s=0.5, seed=11)


def test_same_seed_identical_latency_records(tiny_model):
    a, _ = _run(tiny_model, TCFG)
    b, _ = _run(tiny_model, TCFG)
    assert _records(a) == _records(b)


def test_mid_trace_kill_zero_lost_tokens(tiny_model):
    """The acceptance criterion: seeded open-loop run over 2 replicas,
    kill one mid-trace — every stream drains token-exact with the
    unkilled run (zero lost, zero duplicated, zero mutated tokens)."""
    ref, _ = _run(tiny_model, TCFG)
    out, router = _run(tiny_model, TCFG, kills=[(0.15, 0)])
    assert sum(h.alive for h in router.replicas.health) == 1
    assert router.replicas.requeued, "kill mid-decode must re-route work"
    ref_toks = {r.uid: tuple(r.tokens_out) for r in ref}
    out_toks = {r.uid: tuple(r.tokens_out) for r in out}
    assert ref_toks == out_toks
    m = router.metrics()
    assert m["requests_finished"] == TCFG.n_requests
    # re-routed streams kept their original arrival stamp: TTFT spans
    # replicas, so no re-routed record can claim a NEGATIVE queueing delay
    for r in out:
        assert r.first_token_at > r.created_at > 0.0


def test_failover_latency_record_spans_replicas(tiny_model):
    """A re-routed stream's token_times must be monotone across the kill
    boundary — early tokens stamped on the dead replica, later ones on
    the survivor, one record."""
    out, router = _run(tiny_model, TCFG, kills=[(0.15, 0)])
    carried = {c.uid for c in router.replicas.requeued}
    assert carried
    for r in out:
        if r.uid in carried:
            assert len(r.token_times) == len(r.tokens_out)
            assert all(b >= a for a, b in zip(r.token_times,
                                              r.token_times[1:]))


def test_deadline_shedding_honest_accounting(tiny_model):
    """One replica + crushing load + tight deadline: some requests shed
    from the ROUTER queue; offered = finished + shed + rejected and shed
    SLO-stamped requests count as attainment misses."""
    tcfg = TrafficConfig(rate_rps=200.0, n_requests=24, vocab=512,
                         prompt_lens=((4, 10),), output_lens=((6, 10),),
                         slo_ttft_s=0.05, deadline_s=0.10, seed=2)
    recs, router = _run(tiny_model, tcfg, replicas=1, max_batch=2,
                        step_cost=0.02)
    m = router.metrics()
    assert m["requests_shed"] > 0
    assert (m["requests_offered"]
            == m["requests_finished"] + m["requests_shed"]
            + m["requests_rejected"])
    assert m["slo_attainment"] <= 1.0 - m["requests_shed"] / tcfg.n_requests
    # shed requests are in the final records, marked done, zero tokens
    shed = [r for r in recs if not r.tokens_out]
    assert len(shed) == m["requests_shed"]
    assert all(r.done and r.first_token_at == 0.0 for r in shed)


def test_no_shedding_without_deadline(tiny_model):
    """deadline_s=0 disables shedding: the same crushing load just queues
    (open loop: the delay lands in TTFT, nothing is dropped)."""
    tcfg = TrafficConfig(rate_rps=200.0, n_requests=24, vocab=512,
                         prompt_lens=((4, 10),), output_lens=((6, 10),),
                         slo_ttft_s=0.05, deadline_s=0.0, seed=2)
    recs, router = _run(tiny_model, tcfg, replicas=1, max_batch=2,
                        step_cost=0.02)
    m = router.metrics()
    assert m["requests_shed"] == 0
    assert m["requests_finished"] == tcfg.n_requests
    # overload with no shedding: queueing delay shows up in tail TTFT
    assert m["ttft_p99_s"] > m["ttft_p50_s"] > 0.0
    assert m["slo_attainment"] < 1.0


def test_headroom_gate_bounds_engine_queues(tiny_model):
    """The router only forwards to a replica with load < max_batch, so an
    engine's load never exceeds max_batch while the ROUTER holds the rest
    (where deadlines can still shed them)."""
    cfg, model, params = tiny_model
    clk = VirtualClock()
    scfg = ServeConfig(max_batch=2, max_len=64, batched=True,
                       prefill_chunk=8)
    engines = [ServeEngine(model, params, CCFG, scfg, clock=clk)
               for _ in range(2)]
    rs = ReplicaSet(engines, clock=clk, step_cost=lambda i: 0.02)
    router = SLORouter(rs)
    t0 = clk.now()
    rng = np.random.default_rng(0)
    for i in range(12):                     # burst: all arrive at once
        router.offer(Request(uid=i,
                             prompt=rng.integers(0, cfg.vocab, 8)
                             .astype(np.int32),
                             max_new_tokens=4, created_at=t0 + 1e-9))
    for _ in range(400):
        router._dispatch()
        for e in engines:
            assert e.load() <= scfg.max_batch
        if not router.pending and not any(e.busy() for e in engines):
            break
        rs.step()
    assert sum(len(e._retired) for e in engines) == 12


def test_slow_replica_demoted_under_traffic(tiny_model):
    """End-to-end: a 100x straggler demotes mid-trace and the router stops
    routing NEW arrivals to it (resident work still finishes)."""
    tcfg = TrafficConfig(rate_rps=25.0, n_requests=24, vocab=512,
                         prompt_lens=((4, 10),), output_lens=((8, 12),),
                         slo_ttft_s=0.5, seed=5)
    recs, router = _run(tiny_model, tcfg, replicas=2, slow_replica=0)
    assert router.replicas.health[0].demoted
    assert router.metrics()["requests_finished"] == tcfg.n_requests
    # the fast replica served the overwhelming majority
    served = [len(e._retired) for e in router.replicas.engines]
    assert served[1] > served[0]

"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs; plus
prefill/decode vs full-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeConfig
from repro.models import registry

jax.config.update("jax_platform_name", "cpu")

# CPU execution tests use f32 compute: XLA-CPU's thunk runtime has flaky
# bf16xbf16->f32 dot support; the dry-run (compile-only) keeps bf16.
CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)

ALL_ARCHS = list(registry.ALIASES.keys())


def _batch_for(cfg, key, b, s):
    batch = {}
    if cfg.input_embeds:
        batch["inputs_embeds"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        if cfg.mrope_sections:
            pos = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
            batch["positions"] = jnp.stack([pos, pos, pos])
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg, model = registry.load(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    b, s = 2, 16
    batch = _batch_for(cfg, jax.random.PRNGKey(1), b, s)
    logits = jax.jit(lambda p, bt: model.forward(p, bt, CCFG))(params, batch)
    if cfg.n_codebooks:
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_decreases_loss(arch):
    """One SGD step on the QAT train loss must reduce it (gradients flow
    through every layer incl. fake-quant STE)."""
    cfg, model = registry.load(arch, smoke=True)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=4.0)
        model = registry.build_model(cfg)
    ccfg = dataclasses.replace(CCFG, qat=True)
    params = model.init_params(jax.random.PRNGKey(0), ccfg)
    b, s = 2, 16
    batch = _batch_for(cfg, jax.random.PRNGKey(1), b, s)
    if cfg.n_codebooks:
        batch["labels"] = jax.random.randint(jax.random.PRNGKey(2), (b, s, cfg.n_codebooks), 0, cfg.vocab)
    else:
        batch["labels"] = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)

    def loss_fn(p):
        logits = model.forward(p, batch, ccfg)
        lab = batch["labels"].reshape(-1)
        lg = logits.reshape(-1, cfg.vocab)
        return -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(lg, -1), lab[:, None], 1))

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    # normalized SGD step: robust across families (mamba's exp-cumsum dynamics
    # blow up under raw lr=0.5 steps)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    lr = 0.1 / (gnorm + 1e-6)
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), f"loss did not decrease: {l0} -> {l1}"


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if not registry.get_config(a, smoke=True).input_embeds])
def test_prefill_decode_consistency(arch):
    """decode_step(prefill(x)) must equal the full forward at the same
    position. MoE archs use a large capacity factor: capacity-drop patterns
    are batch-size dependent by design (GShard semantics)."""
    cfg, model = registry.load(arch, smoke=True)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=50.0)
        model = registry.build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    b, s = 2, 16
    batch = _batch_for(cfg, jax.random.PRNGKey(1), b, s)
    logits_p, cache = model.prefill(params, batch, CCFG, max_len=s + 4)
    dtok = jnp.argmax(logits_p[:, -1], -1)[:, None]
    logits_d, cache = model.decode_step(params, {"tokens": dtok}, cache, CCFG)
    toks = jnp.concatenate([batch["tokens"], dtok], 1)
    full = model.forward(params, {"tokens": toks}, CCFG)
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    pf_err = float(jnp.max(jnp.abs(full[:, s - 1] - logits_p[:, 0]))) / scale
    dec_err = float(jnp.max(jnp.abs(full[:, -1] - logits_d[:, 0]))) / scale
    assert pf_err < 1e-4, f"prefill mismatch {pf_err}"
    assert dec_err < 1e-4, f"decode mismatch {dec_err}"


@pytest.mark.parametrize("arch", ["recurrentgemma-2b"])
def test_windowed_ring_buffer_long_decode(arch):
    """Decode far past the window: ring buffer must keep matching the full
    forward (positions > window wrap around slots)."""
    cfg, model = registry.load(arch, smoke=True)  # window=16
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    b, s = 1, 12
    batch = _batch_for(cfg, jax.random.PRNGKey(1), b, s)
    logits_p, cache = model.prefill(params, batch, CCFG, max_len=64)
    toks = batch["tokens"]
    step = jax.jit(lambda p, t, c: model.decode_step(p, {"tokens": t}, c, CCFG))
    for i in range(12):  # 12+12 > window=16 => wraps
        nxt = jnp.argmax(logits_p[:, -1] if i == 0 else logits_d[:, 0], -1)[:, None]
        logits_d, cache = step(params, nxt, cache)
        toks = jnp.concatenate([toks, nxt], 1)
    full = model.forward(params, {"tokens": toks}, CCFG)
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    err = float(jnp.max(jnp.abs(full[:, -1] - logits_d[:, 0]))) / scale
    assert err < 1e-4, f"ring-buffer decode mismatch {err}"


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "olmoe-1b-7b", "mamba2-370m"])
def test_serve_fp4_matches_ptq_dense(arch):
    """serve_fp4 params (packed FP4) must produce the same logits as the
    dense model whose weights were PTQ-roundtripped — the FP4 serving path is
    exactly dequant(quant(w))."""
    from repro.core import quant as Q
    cfg, model = registry.load(arch, smoke=True)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=50.0)
        model = registry.build_model(cfg)
    train_cfg = CCFG
    serve_cfg = dataclasses.replace(CCFG, mode="serve_fp4")
    params = model.init_params(jax.random.PRNGKey(0), train_cfg)

    from repro.core import cascade as C
    sparams = C.tree_to_serve_fp4(params, serve_cfg)
    b, s = 2, 8
    batch = _batch_for(cfg, jax.random.PRNGKey(1), b, s)
    out_fp4 = model.forward(sparams, batch, serve_cfg)

    def qdq_dense(p):
        def conv(d):
            if isinstance(d, dict):
                if "w" in d and d["w"].ndim == 2:
                    packed, scale = Q.quantize_weight(d["w"].astype(jnp.float32))
                    nd = dict(d)
                    nd["w"] = Q.dequantize_weight(packed, scale, jnp.float32)
                    return nd
                if "w" in d and d["w"].ndim >= 3:
                    qfn = lambda w: Q.quantize_weight(w.astype(jnp.float32))
                    dfn = lambda c, sc: Q.dequantize_weight(c, sc, jnp.float32)
                    for _ in range(d["w"].ndim - 2):
                        qfn, dfn = jax.vmap(qfn), jax.vmap(dfn)
                    packed, scale = qfn(d["w"])
                    nd = dict(d)
                    nd["w"] = dfn(packed, scale)
                    return nd
                return {k: conv(v) for k, v in d.items()}
            if isinstance(d, list):
                return [conv(v) for v in d]
            return d
        return conv(p)

    out_dense = model.forward(qdq_dense(params), batch, train_cfg)
    scale = float(jnp.max(jnp.abs(out_dense))) + 1e-9
    err = float(jnp.max(jnp.abs(out_fp4 - out_dense))) / scale
    assert err < 2e-3, f"fp4 serving vs qdq dense mismatch: {err}"

"""Open-loop traffic generator + clock protocol: determinism, distributions.

The generator is the root of the traffic harness's reproducibility claim:
same seed => identical trace (arrival times, prompts, lengths, SLO
stamps). Property tests pin that, plus the statistical contracts — Poisson
mean inter-arrival within tolerance of 1/rate, mixture lengths inside
their configured bounds — and the VirtualClock's monotonicity.
"""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.serve.traffic import (Clock, MonotonicClock, TrafficConfig,
                                 VirtualClock, poisson_trace)

_seeds = st.integers(0, 2**31 - 1)


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------

def test_clock_protocol():
    assert isinstance(MonotonicClock(), Clock)
    assert isinstance(VirtualClock(), Clock)


def test_virtual_clock_advances_and_never_rewinds():
    clk = VirtualClock(start=5.0)
    assert clk.now() == 5.0
    assert clk.advance(1.5) == 6.5
    assert clk.advance_to(6.0) == 6.5      # past target: no-op
    assert clk.advance_to(10.0) == 10.0
    with pytest.raises(AssertionError):
        clk.advance(-0.1)


def test_monotonic_clock_is_monotone():
    clk = MonotonicClock()
    a = clk.now()
    assert clk.now() >= a


# ---------------------------------------------------------------------------
# generator: determinism + shape
# ---------------------------------------------------------------------------

def _sig(trace):
    return [(a.at_s, a.request.uid, a.request.prompt.tolist(),
             a.request.max_new_tokens, a.request.slo_ttft_s,
             a.request.deadline_s) for a in trace]


@settings(max_examples=25, deadline=None)
@given(_seeds)
def test_same_seed_identical_trace(seed):
    cfg = TrafficConfig(rate_rps=12.0, n_requests=20, seed=seed,
                        prompt_lens=((2, 8), (16, 24)), prompt_mix=(3.0, 1.0),
                        output_lens=((1, 4),), slo_ttft_s=0.3, deadline_s=1.0)
    assert _sig(poisson_trace(cfg)) == _sig(poisson_trace(cfg))


def test_different_seed_different_trace():
    cfg = TrafficConfig(n_requests=16, seed=0)
    assert (_sig(poisson_trace(cfg))
            != _sig(poisson_trace(TrafficConfig(n_requests=16, seed=1))))


def test_trace_is_open_loop_shaped():
    """Arrivals sorted, unstamped (the driver re-bases onto its clock),
    SLO fields threaded through to every request."""
    cfg = TrafficConfig(rate_rps=5.0, n_requests=12, slo_ttft_s=0.25,
                        deadline_s=2.0, seed=3)
    trace = poisson_trace(cfg)
    assert len(trace) == 12
    ats = [a.at_s for a in trace]
    assert ats == sorted(ats) and ats[0] > 0.0
    for a in trace:
        assert a.request.created_at == 0.0
        assert a.request.slo_ttft_s == 0.25
        assert a.request.deadline_s == 2.0


@settings(max_examples=20, deadline=None)
@given(_seeds, st.floats(1.0, 50.0))
def test_poisson_mean_interarrival_within_tolerance(seed, rate):
    """Mean gap of n exponential(1/rate) draws concentrates at 1/rate:
    the sample mean is within 5 sigma = 5/(rate*sqrt(n)) of it."""
    n = 512
    cfg = TrafficConfig(rate_rps=rate, n_requests=n, seed=seed)
    ats = np.asarray([a.at_s for a in poisson_trace(cfg)])
    gaps = np.diff(np.concatenate([[0.0], ats]))
    assert abs(gaps.mean() - 1.0 / rate) < 5.0 / (rate * np.sqrt(n))


@settings(max_examples=20, deadline=None)
@given(_seeds,
       st.integers(1, 12), st.integers(0, 12),
       st.integers(1, 12), st.integers(0, 12))
def test_lengths_respect_configured_bounds(seed, plo, pspan, olo, ospan):
    """Every prompt/output length lands inside SOME configured component
    range — the mixture never leaks outside its support."""
    phi, ohi = plo + pspan, olo + ospan
    cfg = TrafficConfig(n_requests=64, seed=seed,
                        prompt_lens=((plo, phi), (plo + 20, phi + 20)),
                        prompt_mix=(1.0, 2.0),
                        output_lens=((olo, ohi),))
    for a in poisson_trace(cfg):
        n = len(a.request.prompt)
        assert (plo <= n <= phi) or (plo + 20 <= n <= phi + 20)
        assert olo <= a.request.max_new_tokens <= ohi


def test_bad_mixture_rejected():
    with pytest.raises(AssertionError):
        poisson_trace(TrafficConfig(prompt_lens=((8, 4),)))      # hi < lo
    with pytest.raises(AssertionError):
        poisson_trace(TrafficConfig(prompt_lens=((4, 8), (2, 3)),
                                    prompt_mix=(1.0,)))          # arity
    with pytest.raises(AssertionError):
        poisson_trace(TrafficConfig(prompt_mix=(0.0,)))          # zero mass

"""Serving-contract auditor: contrast tests for every checker.

Each invariant gets BOTH directions: the shipped engine passes, and a
deliberately broken program (donation dropped, host callback injected,
f64 smuggled in, weights densified, time.* read in serve/, shape leak
forcing a retrace) trips exactly the intended finding. A checker only
earns its place in CI by failing on the bug it was built for.

Layout:
  * checker contrasts on real single-device lowerings (jax.jit here);
  * lint contrasts on source strings (no filesystem);
  * retrace contrasts on a counting jit fn + the real engine;
  * one end-to-end ``audit_engine`` pass over the transformer smoke
    engine with every closure live (sampled + speculative + FP4).

The megatron partial-sum contrast lives in tests/test_sharding.py (it
needs the 8-device mesh environment).
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from repro.analysis import contract, hlo, lint, retrace
from repro.analysis.findings import Finding, gating


def _hlo_of(fn, *args, donate=()):
    f = jax.jit(fn, donate_argnums=donate)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return f.lower(*args).compile().as_text()


needs_donation = pytest.mark.skipif(
    not contract.donation_supported(),
    reason="backend drops buffer donation; check degrades to info")


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------

@needs_donation
def test_donation_aliases_parses_honored_donation():
    x = jnp.zeros((64, 64), jnp.float32)
    text = _hlo_of(lambda c, t: c.at[0].add(t), x, x[0], donate=(0,))
    al = hlo.donation_aliases(text)
    assert al["count"] >= 1 and 0 in al["params"]
    assert all(a["bytes"] > 0 for a in al["aliases"])
    big = [a for a in al["aliases"] if a["bytes"] == 64 * 64 * 4]
    assert big, al["aliases"]


@needs_donation
def test_audit_step_flags_dropped_donation_and_passes_honored():
    """The contrast that motivates the checker: the same cache-update step
    with and without donate_argnums. Undonated -> zero alias entries ->
    a 'donation' error finding on a strict closure; donated -> clean."""
    x = jnp.zeros((64, 64), jnp.float32)
    step = lambda c, t: c.at[0].add(t)
    c = contract.ServingContract()
    assert "decode" in c.strict_donation_closures

    bad_text = _hlo_of(step, x, x[0])                     # no donation
    _, bad = contract.audit_step("decode", bad_text, c, cache_leaves=1,
                                 cache_major_leaves=1)
    assert any(f.check == "donation" and f.level == "error" for f in bad), bad

    good_text = _hlo_of(step, x, x[0], donate=(0,))
    _, good = contract.audit_step("decode", good_text, c, cache_leaves=1,
                                  cache_major_leaves=1)
    assert not [f for f in good if f.check == "donation"], good


@needs_donation
def test_donation_check_ignores_sub_floor_leaves_and_lax_closures():
    """A tiny (sub-floor) donated leaf that the compiler recomputes instead
    of aliasing must NOT gate — rewind's pos vector is the real case — and
    closures outside strict_donation_closures never gate on donation."""
    big = jnp.zeros((64, 64), jnp.float32)     # 16 KiB: above the floor
    pos = jnp.zeros((4,), jnp.int32)           # 16 B: advisory
    # pos output derives from fresh values -> compiler cannot alias it
    step = lambda c, p, t: (c.at[0].add(t), jnp.arange(4, dtype=jnp.int32))
    text = _hlo_of(step, big, pos, big[0], donate=(0, 1))
    c = contract.ServingContract()
    # 2 donated leaves but only 1 at/above the floor -> still clean
    _, fs = contract.audit_step("decode", text, c, cache_leaves=2,
                                cache_major_leaves=1)
    assert not [f for f in fs if f.check == "donation"], fs
    # the same module as an exempt closure with an (impossible) demand of
    # 2 major leaves -> still no gate: rewind/extend donate best-effort
    _, fs = contract.audit_step("rewind", text, c, cache_leaves=2,
                                cache_major_leaves=2)
    assert not [f for f in fs if f.check == "donation"], fs


# ---------------------------------------------------------------------------
# host transfers
# ---------------------------------------------------------------------------

def test_host_transfer_contrast_io_callback():
    """An io_callback smuggled into a step lowers to a host-callback
    custom-call; the checker must name it. The pure-device version of the
    same computation is clean."""
    x = jnp.zeros((8,), jnp.float32)

    def clean(v):
        return v * 2.0

    def dirty(v):
        jax.experimental.io_callback(lambda a: None, None, v)
        return v * 2.0

    import jax.experimental  # io_callback lives here

    assert hlo.host_transfers(_hlo_of(clean, x))["count"] == 0
    ht = hlo.host_transfers(_hlo_of(dirty, x))
    assert ht["count"] >= 1, ht
    c = contract.ServingContract()
    _, fs = contract.audit_step("decode", _hlo_of(dirty, x), c)
    assert any(f.check == "host-transfer" and f.level == "error"
               for f in fs), fs


# ---------------------------------------------------------------------------
# dtypes / packed weights
# ---------------------------------------------------------------------------

def test_dtype_audit_flags_forbidden_f64():
    """f64 never ships in a serving step; checked on synthetic HLO because
    CPU jax config in this suite keeps x64 disabled."""
    text = """\
HloModule m

ENTRY %main (p0: f64[8]) -> f64[8] {
  %p0 = f64[8] parameter(0)
  ROOT %a = f64[8] add(f64[8] %p0, f64[8] %p0)
}
"""
    da = hlo.dtype_audit(text)
    assert da["forbidden"], da
    _, fs = contract.audit_step("decode", text, contract.ServingContract())
    assert any(f.check == "dtype" for f in fs), fs


def test_packed_weight_contract_contrast():
    """require_packed_weights: a step whose ENTRY takes u8 code planes
    passes; the densified (all-float params) version of the same step is
    the bug FP4 serving exists to avoid, and must gate."""
    codes = jnp.zeros((32, 16), jnp.uint8)
    scales = jnp.zeros((32, 1), jnp.uint8)
    xf = jnp.zeros((4, 32), jnp.float32)
    wf = jnp.zeros((32, 32), jnp.float32)

    packed_text = _hlo_of(
        lambda c, s, x: x @ (c.astype(jnp.float32)[:, :32][:, :32] + 0.0),
        codes, scales, xf)
    dense_text = _hlo_of(lambda w, x: x @ w, wf, xf)

    c = contract.ServingContract(require_packed_weights=True)
    assert hlo.dtype_audit(packed_text)["packed_params"] >= 1
    _, fs = contract.audit_step("decode", packed_text, c)
    assert not [f for f in fs if f.check == "dtype"], fs
    da = hlo.dtype_audit(dense_text)
    assert da["packed_params"] == 0 and da["float_params"] >= 1
    _, fs = contract.audit_step("decode", dense_text, c)
    assert any("densified" in f.detail for f in fs), fs
    # a param-less closure (write/rewind) is exempt from the packed demand
    _, fs = contract.audit_step("write", dense_text, c, takes_params=False)
    assert not [f for f in fs if "densified" in f.detail], fs


# ---------------------------------------------------------------------------
# collective budget
# ---------------------------------------------------------------------------

def test_collective_budget_violations():
    text = """\
HloModule m

%add_comb (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add.1 = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  ROOT %ar = f32[8] all-reduce(f32[8] %p0), to_apply=%add_comb
}
"""
    cb = hlo.collective_budget(text, max_partial_sum=0)
    assert ("partial-sum all-reduces", 1.0, 0.0) in cb["violations"]
    cb = hlo.collective_budget(text, max_counts={"all-reduce": 0})
    assert any(v[0] == "all-reduce count" for v in cb["violations"])
    cb = hlo.collective_budget(text, max_bytes=8.0, max_partial_sum=None)
    assert any(v[0] == "collective bytes" for v in cb["violations"])
    assert hlo.collective_budget(text, max_partial_sum=1)["violations"] == []
    # psum-exempt closures skip the cap but still record the count
    st, fs = contract.audit_step(
        "extend", text, contract.ServingContract(max_partial_sum_allreduces=0))
    assert st["partial_sum_allreduces"] == 1 and not fs
    _, fs = contract.audit_step(
        "decode", text, contract.ServingContract(max_partial_sum_allreduces=0))
    assert any(f.check == "collective-budget" for f in fs), fs


# ---------------------------------------------------------------------------
# lint: source-string contrasts
# ---------------------------------------------------------------------------

def test_lint_time_read_in_serve_trips_and_traffic_exempt():
    src = "import time\n\ndef f():\n    return time.monotonic()\n"
    fs = lint.lint_source(src, "src/repro/serve/router.py")
    assert [f for f in fs if f.check == "time-read"
            and not f.allowlisted], fs
    assert any("monotonic" in f.detail for f in fs)
    # traffic.py owns the wall-clock shim: same source, no finding
    assert lint.lint_source(src, "src/repro/serve/traffic.py") == []
    # and outside serve/ the rule does not apply
    assert lint.lint_source(src, "src/repro/core/cascade.py") == []


def test_lint_host_sync_in_jit_closure_bodies():
    flagged = (
        "import jax, numpy as np\n"
        "@jax.jit\n"
        "def step(c):\n"
        "    n = int(np.asarray(c)[0])\n"
        "    return c * n\n"
        "g = jax.jit(lambda x: x.item() + 1)\n"
        "def h(x):\n"
        "    return jax.device_get(x)\n"
        "hc = jax.jit(h)\n")
    fs = lint.lint_source(flagged, "src/repro/serve/engine.py")
    hits = [f for f in fs if f.check == "host-sync-in-jit"]
    assert len(hits) == 3, fs
    # the same host syncs OUTSIDE any jit target are host-side bookkeeping
    clean = ("import numpy as np\n"
             "def admit(x):\n"
             "    return np.asarray(x).item()\n")
    assert lint.lint_source(clean, "src/repro/serve/engine.py") == []


def test_lint_jax_config_global_and_allowlist_marker():
    src = "import jax\njax.config.update('jax_enable_x64', True)\n"
    fs = lint.lint_source(src, "src/repro/core/cascade.py")
    assert [f for f in fs if f.check == "jax-config-global"
            and not f.allowlisted], fs
    # marker on the line above downgrades to allowlisted (still visible)
    src2 = ("import jax\n"
            "# lint: allow[jax-config-global] — designated site\n"
            "jax.config.update('jax_enable_x64', True)\n")
    fs2 = lint.lint_source(src2, "src/repro/core/cascade.py")
    assert fs2 and all(f.allowlisted for f in fs2), fs2
    assert gating(fs2) == []


def test_lint_pallas_call_must_thread_interpret():
    bad = "import jax.experimental.pallas as pl\nf = pl.pallas_call(k)\n"
    fs = lint.lint_source(bad, "src/repro/kernels/fp4.py")
    assert [f for f in fs if f.check == "pallas-interpret"], fs
    good = ("import jax.experimental.pallas as pl\n"
            "f = pl.pallas_call(k, interpret=True)\n"
            "g = pl.pallas_call(k, **kw)\n")
    assert lint.lint_source(good, "src/repro/kernels/fp4.py") == []


def test_shipped_tree_lints_clean():
    """The repo's own src/repro passes its lint: zero unallowlisted
    findings (satellite b — every genuine finding fixed or justified)."""
    fs = lint.lint_paths(["src/repro"], base=REPO)
    assert gating(fs) == [], "\n".join(str(f.__dict__) for f in gating(fs))
    # the designated global-config site stays VISIBLE as allowlisted
    assert any(f.check == "jax-config-global" and f.allowlisted for f in fs)


# ---------------------------------------------------------------------------
# retrace guard
# ---------------------------------------------------------------------------

def test_retrace_findings_synthetic_shape_leak():
    class FakeEngine:
        def step_closures(self):
            return {"decode": {"fn": self._fn}}
        def __init__(self, fn):
            self._fn = fn

    f = jax.jit(lambda x: x * 2)
    eng = FakeEngine(f)
    assert retrace.compile_counts(eng)["decode"] == 0
    fs = retrace.retrace_findings(eng, require_dispatched=("decode",))
    assert any(f_.level == "error" and "verified nothing" in f_.detail
               for f_ in fs), fs
    f(jnp.zeros((4,)))
    assert retrace.retrace_findings(eng, require_dispatched=("decode",)) == []
    f(jnp.zeros((5,)))                      # shape leak: second trace
    fs = retrace.retrace_findings(eng)
    assert any(f_.level == "error" and "compiled 2x" in f_.detail
               for f_ in fs), fs


# ---------------------------------------------------------------------------
# end-to-end: the real engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def spec_engine():
    """Transformer smoke engine with EVERY closure live: FP4 params,
    sampling + speculation. Shared across the end-to-end tests."""
    import warnings
    from repro.core import cascade
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg, model = registry.load(registry.FAMILY_SMOKE["transformer"],
                               smoke=True)
    tc = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), tc)
    ccfg = CascadeConfig(mode="serve_fp4", compute_dtype=jnp.float32)
    params = cascade.tree_to_serve_fp4(params, ccfg)
    scfg = ServeConfig(max_batch=2, max_len=48, temperature=0.7,
                       draft_len=2, prefill_chunk=8)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return cfg, ServeEngine(model, params, ccfg, scfg)


def test_audit_engine_shipped_transformer_is_clean(spec_engine):
    """The acceptance bar: zero gating findings over every step closure of
    the sampled+speculative FP4 transformer engine, and the registry
    exposes the full closure set."""
    cfg, eng = spec_engine
    res = contract.audit_engine(eng)
    assert gating(res["findings"]) == [], [
        f.__dict__ for f in gating(res["findings"])]
    names = set(res["closures"])
    assert {"extend", "write", "verify", "rewind",
            "spec_sample", "sample"} <= names, names
    assert res["contract"]["require_packed_weights"] is True
    for name in ("extend", "verify", "spec_sample", "sample"):
        assert res["closures"][name]["packed_params"] > 0, name
        assert res["closures"][name]["host_transfers"] == 0, name


def test_engine_closures_compile_once_over_trace(spec_engine):
    """The retrace guard on the real thing: a served trace with ragged
    prompts/outputs compiles each dispatched closure exactly once."""
    import warnings
    from repro.serve.engine import Request
    cfg, eng = spec_engine
    rng = np.random.default_rng(0)
    for i, n in enumerate([9, 4, 13]):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                           max_new_tokens=5))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        while eng.busy():
            eng.step()
    counts = retrace.compile_counts(eng)
    fs = retrace.retrace_findings(eng, require_dispatched=("extend",))
    assert gating(fs) == [], [f.__dict__ for f in gating(fs)]
    assert counts["extend"] == 1, counts
    for name, n in counts.items():
        assert n <= 1, (name, counts)
    # AOT audit composes: auditing did not add dispatch-cache entries
    contract.audit_engine(eng)
    assert retrace.compile_counts(eng) == counts


def test_audit_engine_slotwise_is_info_not_silent():
    """A non-batched engine has no step registry; the auditor must SAY so
    (info finding), never return an empty clean result."""
    class Slotwise:
        batched = False
    res = contract.audit_engine(Slotwise(), contract.ServingContract())
    assert res["closures"] == {}
    assert res["findings"] and res["findings"][0].level == "info"
    assert gating(res["findings"]) == []


# ---------------------------------------------------------------------------
# findings plumbing
# ---------------------------------------------------------------------------

def test_finding_roundtrip_and_gating():
    f = Finding("donation", "decode", "dropped", level="error")
    assert Finding.from_dict(f.to_dict()) == f
    a = Finding("donation", "decode", "known", level="error",
                allowlisted=True)
    i = Finding("audit", "engine", "fyi", level="info")
    assert gating([f, a, i]) == [f]

"""Speculative decode: draft/verify/rewind across every registry family.

Speculation may only change SPEED, never output: every committed token is
the model's own greedy argmax given its prefix, so spec-on must be
token-exact with the plain (PR 2) single-token decode path — including at
ring-buffer wrap boundaries, through failover, and for eos / max_new /
context-limit retirement that fires mid-acceptance. A full rewind
(keep=0) must be the identity on the pre-verify cache for every cache
family: dense KV, MLA latents, ring buffers, and checkpointed recurrent
{conv, h, ssd} state.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.spec import ngram_propose

jax.config.update("jax_platform_name", "cpu")

CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)


def _load(arch):
    cfg, model = registry.load(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


@pytest.fixture(scope="module", params=sorted(registry.FAMILY_SMOKE), ids=str)
def family_model(request):
    return (request.param,) + _load(registry.FAMILY_SMOKE[request.param])


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, int(n)).astype(np.int32),
                    max_new_tokens=max_new) for i, n in enumerate(lens)]


def _run(model, params, cfg, lens, scfg, max_new=4, seed=0, max_steps=400):
    eng = ServeEngine(model, params, CCFG, scfg)
    reqs = _requests(cfg, lens, max_new=max_new, seed=seed)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps)
    return reqs, eng


# ---------------------------------------------------------------------------
# drafter (model-free prompt lookup)
# ---------------------------------------------------------------------------

def test_ngram_propose_matches_longest_recent_suffix():
    ctx = [1, 2, 3, 9, 1, 2, 3]
    # suffix 3-gram (1,2,3) occurred at 0; continuation is [9, 1, 2]
    out, ke = ngram_propose(ctx, 3, 3)
    assert out.tolist() == [9, 1, 2] and ke == 3
    # most RECENT earlier occurrence wins
    ctx = [5, 7, 1, 5, 7, 2, 5, 7]
    out, ke = ngram_propose(ctx, 1, 2)
    assert out.tolist() == [2] and ke == 1


def test_ngram_propose_falls_back_to_shorter_ngrams_and_misses():
    # no 3- or 2-gram match, but the 1-gram suffix [4] occurred earlier
    out, ke = ngram_propose([4, 1, 2, 4], 2, 3)
    assert out.tolist() == [1, 2] and ke == 2
    # total miss -> zeros AND k_eff == 0 (a free, guaranteed-unscored guess)
    out, ke = ngram_propose([1, 2, 3], 2, 3)
    assert out.tolist() == [0, 0] and ke == 0
    out, ke = ngram_propose([7], 2, 3)
    assert out.tolist() == [0, 0] and ke == 0
    # continuation shorter than k is zero-padded, and k_eff marks the cut
    out, ke = ngram_propose([9, 3, 9], 3, 1)
    assert out.tolist() == [3, 9, 0] and ke == 2


def test_ngram_propose_k_eff_distinguishes_real_token_zero_from_padding():
    """Token id 0 is a legitimate vocab token: a proposal OF token 0 must be
    scoreable (k_eff covers it) while zero-PADDING must not — conflating
    them would score padding as a real draft (accepted with probability
    p(0) under sampled speculation, spuriously matched under greedy)."""
    # suffix [5] recurs; its continuation is genuinely [0, 0, 7]
    out, ke = ngram_propose([5, 0, 0, 7, 1, 5], 3, 1)
    assert out.tolist() == [0, 0, 7] and ke == 3
    # a real token-0 proposal followed by zero padding: positionally
    # indistinguishable in the array — only k_eff tells real from padding
    out, ke = ngram_propose([4, 0, 4], 3, 1)
    assert out.tolist() == [0, 4, 0] and ke == 2
    # and a 1-token continuation that IS token 0
    out, ke = ngram_propose([7, 0, 7], 1, 1)
    assert out.tolist() == [0] and ke == 1


def test_ngram_propose_prefers_latest_full_continuation():
    """Self-repetitive tails put the most recent match flush against the
    context end (1-token continuation); an earlier occurrence with a full
    k-token continuation must win so the proposal length does not collapse
    — the speculative acceptance ceiling depends on it."""
    ctx = [0] * 12
    out, ke = ngram_propose(ctx, 4, 3)
    assert out.tolist() == [0, 0, 0, 0] and ke == 4
    # no full continuation exists anywhere -> most recent partial one
    out, ke = ngram_propose([8, 9, 8, 9, 8], 4, 2)
    assert ke < 4 and out[:ke].tolist() == [9, 8][:ke]


# ---------------------------------------------------------------------------
# per-family token-exact parity (the tentpole guarantee)
# ---------------------------------------------------------------------------

def test_family_spec_equals_plain_decode_token_exact(family_model):
    """Spec-on (draft/verify/rewind) emits EXACTLY the plain greedy decode
    stream for every family — dense KV, MLA, ring + RG-LRU, conv + SSD."""
    fam, cfg, model, params = family_model
    lens = [8, 5, 12, 3]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8), max_new=6)
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=64, batched=True,
                                prefill_chunk=8, draft_len=3), max_new=6)
    assert eng.spec, f"{fam} must take the speculative path"
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (fam, a.uid, a.tokens_out, b.tokens_out)


def test_family_spec_with_budgeted_chunked_prefill_token_exact(family_model):
    """Speculation interleaved with budgeted chunked prefill admissions."""
    fam, cfg, model, params = family_model
    lens = [17, 8, 29]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8, token_budget=8), max_new=5)
    out, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8, token_budget=8, draft_len=4),
                  max_new=5)
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (fam, a.uid, a.tokens_out, b.tokens_out)


def test_family_spec_full_rewind_is_identity(family_model):
    """spec_rewind(keep=0) after a verify pass restores the pre-verify
    cache BIT-EXACTLY — rejected ring writes, recurrent checkpoints and
    position tables all roll back."""
    fam, cfg, model, params = family_model
    b = 3
    cache = model.init_cache(b, 32, dtype=jnp.float32)
    for i, n in enumerate([5, 8, 3]):       # slots at different positions
        toks = jnp.asarray(np.arange(n)[None, :] % cfg.vocab, jnp.int32)
        _, sub = model.prefill(params, {"tokens": toks}, CCFG, max_len=32)
        cache = model.write_cache(cache, sub, i)
    before = jax.tree.leaves(cache)
    chunk = jnp.asarray(np.arange(b * 4).reshape(b, 4) % cfg.vocab, jnp.int32)
    logits, after, ckpt = model.spec_verify(params, {"tokens": chunk}, cache, CCFG)
    assert logits.shape == (b, 4, cfg.vocab)
    rewound = model.spec_rewind(after, ckpt, jnp.zeros((b,), jnp.int32))
    restored = jax.tree.leaves(rewound)
    assert len(before) == len(restored)
    for x, y in zip(before, restored):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y)), fam


# ---------------------------------------------------------------------------
# ring-buffer wrap + draft clamping (griffin)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def griffin_w8():
    cfg, model = registry.load("recurrentgemma-2b", smoke=True)
    cfg = dataclasses.replace(cfg, window=8)
    model = registry.build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


def test_spec_rewind_at_ring_wrap_token_exact(griffin_w8):
    """Draft chunks that straddle the ring-buffer wrap: rejected writes
    clobber live in-window entries, so the rewind must RESTORE them (a pos
    rewind alone would silently drop attention context)."""
    cfg, model, params = griffin_w8
    lens = [23, 40, 9, 16]                  # prompts well past window=8
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8), max_new=8)
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=64, batched=True,
                                prefill_chunk=8, draft_len=5), max_new=8)
    assert eng.spec
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_spec_draft_len_clamped_to_ring(griffin_w8):
    """A (1+K) verify chunk must fit the ring like any extend chunk."""
    cfg, model, params = griffin_w8
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=1, max_len=64, batched=True,
                                  prefill_chunk=8, draft_len=32))
    assert eng.spec and eng._draft_len == 7   # window 8 -> chunk of 8


# ---------------------------------------------------------------------------
# retirement fires at exactly the right token mid-acceptance
# ---------------------------------------------------------------------------

def test_spec_eos_mid_acceptance_retires_exactly(family_model):
    """eos emitted inside an accepted run must truncate the stream at the
    same token plain decode stops at (never expose post-eos drafts)."""
    fam, cfg, model, params = family_model
    probe, _ = _run(model, params, cfg, [8],
                    ServeConfig(max_batch=1, max_len=64, batched=True),
                    max_new=6)
    eos = probe[0].tokens_out[2]            # a mid-stream token
    ref, _ = _run(model, params, cfg, [8],
                  ServeConfig(max_batch=1, max_len=64, batched=True,
                              eos_id=eos), max_new=6)
    out, _ = _run(model, params, cfg, [8],
                  ServeConfig(max_batch=1, max_len=64, batched=True,
                              eos_id=eos, draft_len=4), max_new=6)
    assert out[0].tokens_out == ref[0].tokens_out, fam
    assert out[0].tokens_out[-1] == eos or len(out[0].tokens_out) == 6


# ---------------------------------------------------------------------------
# failover mid-speculation
# ---------------------------------------------------------------------------

def test_spec_failover_carries_only_accepted_tokens(family_model):
    """Replica death mid-speculation: the rebuilt prompt contains the
    original prompt + every COMMITTED token and nothing else (unaccepted
    drafts never enter ``tokens_out``), and the survivor finishes the
    stream token-exact with an unkilled plain-decode run."""
    from repro.serve.elastic import ReplicaSet
    fam, cfg, model, params = family_model
    ref, _ = _run(model, params, cfg, [8],
                  ServeConfig(max_batch=1, max_len=64, batched=True),
                  max_new=8, seed=3)
    scfg = ServeConfig(max_batch=1, max_len=64, batched=True, draft_len=3)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(2)])
    victim = _requests(cfg, [8], max_new=8, seed=3)[0]
    rs.submit(victim)
    for _ in range(3):                      # prefill + a couple of spec steps
        rs.step()
    emitted = list(victim.tokens_out)
    killed_on = next(i for i, e in enumerate(rs.engines) if victim in e.slots)
    rs.kill_replica(killed_on)
    clone = rs.requeued[0]
    # the carry invariant: prompt grew by exactly the committed tokens
    assert clone.prompt_carried == len(emitted)
    assert clone.prompt.tolist() == victim.prompt.tolist() + emitted
    rs.drain(max_steps=200)
    assert clone.done
    assert clone.tokens_out == ref[0].tokens_out, (fam, clone.tokens_out,
                                                   ref[0].tokens_out)


# ---------------------------------------------------------------------------
# degeneration + gating
# ---------------------------------------------------------------------------

def test_draft_len_zero_degenerates_to_plain_batched(family_model):
    """draft_len=0 must be the PR 2 path: no spec attributes consulted, one
    decode dispatch per step."""
    fam, cfg, model, params = family_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True,
                                  draft_len=0))
    assert eng.batched and not eng.spec
    for r in _requests(cfg, [8, 8]):
        eng.submit(r)
    calls = []
    inner = eng._decode_fn
    eng._decode_fn = lambda *a: calls.append(1) or inner(*a)
    eng.step()
    assert len(calls) == 1


def test_sampling_keeps_speculation_enabled_and_deterministic():
    """temperature > 0 no longer disables speculation: the engine runs
    speculative SAMPLING (rejection resampling), which must still be
    seed-deterministic (one fold_in counter, all draws on device)."""
    cfg, model, params = _load("codeqwen1.5-7b")
    scfg = ServeConfig(max_batch=2, max_len=64, batched=True, draft_len=4,
                       temperature=0.9, top_k=5, sample_seed=11)
    a, eng = _run(model, params, cfg, [8, 5], scfg, max_new=5)
    assert eng.spec and eng.effective_mode == "spec-sampled"
    assert not eng.downgrades
    b_, _ = _run(model, params, cfg, [8, 5], scfg, max_new=5)
    for ra, rb in zip(a, b_):
        assert ra.tokens_out == rb.tokens_out
        assert all(0 <= t < cfg.vocab for t in ra.tokens_out)


def test_mode_downgrades_warn_once_and_surface_in_metrics():
    """Silent mode downgrades are gone: every fallback warns at engine
    construction and metrics()['effective_mode'] reports the path that
    actually runs (benches assert on it instead of trusting the config)."""
    cfg, model = registry.load("musicgen-large", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    with pytest.warns(RuntimeWarning, match="multi-codebook"):
        eng = ServeEngine(model, params, CCFG,
                          ServeConfig(max_batch=2, max_len=64, batched=True))
    assert not eng.batched
    assert eng.effective_mode == "slotwise-greedy"
    assert eng.metrics()["downgrades"]
    # draft_len on a slot-wise engine: speculation needs the batched grid
    with pytest.warns(RuntimeWarning, match="speculative"):
        eng = ServeEngine(model, params, CCFG,
                          ServeConfig(max_batch=2, max_len=64, batched=True,
                                      draft_len=4))
    assert not eng.spec and eng.effective_mode == "slotwise-greedy"
    assert len(eng.metrics()["downgrades"]) == 2
    # a fully-served config emits no warning and no downgrade entries
    cfg2, model2, params2 = _load("codeqwen1.5-7b")
    eng2 = ServeEngine(model2, params2, CCFG,
                       ServeConfig(max_batch=2, max_len=64, batched=True,
                                   draft_len=2))
    assert eng2.spec and eng2.effective_mode == "spec-greedy"
    assert not eng2.metrics()["downgrades"]


# ---------------------------------------------------------------------------
# speculative SAMPLING: distribution exactness (the tentpole guarantee)
#
# Sampled speculation cannot be token-exact with plain sampled decode (the
# draws differ), so the contract is DISTRIBUTIONAL: every committed token is
# drawn from exactly the truncated distribution p that plain sampled decode
# uses. Pinned three ways, per family, on a tiny vocab:
#   * the verify pass and the plain decode step produce the same logits
#     (same p) from the same cache state;
#   * exact enumeration over EVERY possible draft token: the fused
#     accept/resample rule's committed-token law equals p (empirically over
#     a fixed key set — deterministic — plus deterministic branch cases
#     where the law collapses to a point);
#   * the full engine's first decode token matches the EXACT mixture
#     sum_t0 p0(t0) * p1(t1 | t0) computed from the model directly.
# ---------------------------------------------------------------------------

TINY_VOCAB = 8


@pytest.fixture(scope="module", params=sorted(registry.FAMILY_SMOKE), ids=str)
def tiny_family_model(request):
    cfg = registry.get_config(registry.FAMILY_SMOKE[request.param], smoke=True)
    cfg = dataclasses.replace(cfg, vocab=TINY_VOCAB)
    model = registry.build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return request.param, cfg, model, params


def _truncated_p(logits, temperature, top_k):
    from repro.serve.engine import _truncate_logits
    return np.asarray(jax.nn.softmax(
        _truncate_logits(jnp.asarray(logits), temperature, top_k), axis=-1))


def test_family_spec_sampled_verify_rows_share_p_with_plain_decode(
        tiny_family_model):
    """Row 0 of the verify pass and the plain decode step score the SAME
    distribution from the same cache state — the premise that lets the
    acceptance rule claim it samples from plain decode's p."""
    fam, cfg, model, params = tiny_family_model
    prompt = jnp.asarray([[3, 1, 4, 1, 5, 2, 6]], jnp.int32) % cfg.vocab
    _, cache_a = model.prefill(params, {"tokens": prompt}, CCFG, max_len=32)
    _, cache_b = model.prefill(params, {"tokens": prompt}, CCFG, max_len=32)
    dec, _ = model.decode_step(params, {"tokens": jnp.asarray([[2]], jnp.int32)},
                               cache_a, CCFG)
    chunk = jnp.asarray([[2, 5, 0]], jnp.int32)     # pending + 2 drafts
    ver, _, _ = model.spec_verify(params, {"tokens": chunk}, cache_b, CCFG)
    np.testing.assert_allclose(np.asarray(dec).reshape(-1),
                               np.asarray(ver[0, 0]).reshape(-1),
                               rtol=2e-5, atol=2e-5)


def test_family_spec_sampled_marginal_exact_enumeration(tiny_family_model):
    """Enumerate EVERY possible draft token d on real verify logits: the
    fused accept/resample law's committed-token marginal must equal the
    truncated p — p(d)*1[t=d] + (1-p(d))*residual_d(t) = p(t) — at the
    first row (acceptance + residual resample) AND, conditioned on
    acceptance, at the second row (teacher-forced continuation)."""
    from repro.serve.engine import spec_sample_accept
    fam, cfg, model, params = tiny_family_model
    T, top_k, v = 0.8, 5, cfg.vocab
    prompt = jnp.asarray([[1, 6, 2, 0, 3, 3, 7, 4]], jnp.int32) % v
    _, cache = model.prefill(params, {"tokens": prompt}, CCFG, max_len=32)
    n = 4096
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(9), i))(
        jnp.arange(n))
    keff = jnp.asarray([2], jnp.int32)

    @jax.jit
    def run_keys(logits, drafts):       # compiled ONCE, reused per d0
        return jax.vmap(
            lambda k: spec_sample_accept(logits, drafts, keff, k, T, top_k)
        )(keys)

    for d0 in range(v):
        chunk = jnp.asarray([[5, d0, 2]], jnp.int32)    # pending, d0, d1
        logits, _, _ = model.spec_verify(params, {"tokens": chunk}, cache, CCFG)
        p0 = _truncated_p(logits, T, top_k)[0, 0]
        p1 = _truncated_p(logits, T, top_k)[0, 1]
        a_all, t_all = run_keys(logits, chunk[:, 1:])
        a_all = np.asarray(a_all).ravel()
        t_all = np.asarray(t_all).ravel()
        first = np.where(a_all > 0, d0, t_all)
        emp0 = np.bincount(first, minlength=v) / n
        assert 0.5 * np.abs(emp0 - p0).sum() < 0.06, (fam, d0, emp0, p0)
        acc = a_all >= 1
        if acc.sum() > 400:      # second-row law, conditioned on acceptance
            second = np.where(a_all[acc] > 1, 2, t_all[acc])
            emp1 = np.bincount(second, minlength=v) / acc.sum()
            assert 0.5 * np.abs(emp1 - p1).sum() < 0.1, (fam, d0, emp1, p1)
        # acceptance frequency itself follows p0(d0)
        assert abs(acc.mean() - p0[d0]) < 0.05, (fam, d0, acc.mean(), p0[d0])


def test_spec_sampled_branch_enumeration_deterministic():
    """The branches whose law collapses to a point, enumerated exactly:
    p(d)=1 always accepts; a draft outside top-k always rejects and the
    residual NEVER returns the rejected token; k_eff=0 (drafter miss /
    inactive slot) ignores drafts entirely and samples row 0's p; padded
    positions beyond k_eff are never accepted even when p(pad token) = 1."""
    from repro.serve.engine import spec_sample_accept
    v, big = 6, 50.0
    keys = [jax.random.PRNGKey(i) for i in range(32)]
    # p concentrated on the draft -> accept probability 1, bonus from row 1
    logits = np.full((1, 2, v), -big, np.float32)
    logits[0, 0, 3] = big                      # p0 = delta(3)
    logits[0, 1, 1] = big                      # bonus row = delta(1)
    L = jnp.asarray(logits)
    for k in keys:
        a, t = spec_sample_accept(L, jnp.asarray([[3]]), jnp.asarray([1]),
                                  k, 1.0, 0)
        assert int(a[0]) == 1 and int(t[0]) == 1
    # draft outside top-k: p(d) = 0 -> always reject; residual = p, never d
    logits = np.zeros((1, 2, v), np.float32)
    logits[0, 0] = [5.0, 4.0, 3.0, -big, 0.0, 0.0]
    L = jnp.asarray(logits)
    seen = set()
    for k in keys:
        a, t = spec_sample_accept(L, jnp.asarray([[3]]), jnp.asarray([1]),
                                  k, 1.0, 3)
        assert int(a[0]) == 0 and int(t[0]) != 3
        seen.add(int(t[0]))
    assert seen <= {0, 1, 2}                   # top-3 truncated support
    # k_eff = 0: drafts ignored, committed token ~ p0 (here a point mass)
    logits = np.full((1, 2, v), -big, np.float32)
    logits[0, 0, 2] = big
    L = jnp.asarray(logits)
    for k in keys:
        a, t = spec_sample_accept(L, jnp.asarray([[2]]), jnp.asarray([0]),
                                  k, 1.0, 0)
        assert int(a[0]) == 0 and int(t[0]) == 2
    # padding past k_eff never accepted even if the model loves token 0
    logits = np.full((1, 3, v), -big, np.float32)
    logits[0, 0, 4] = big                      # real draft 4: accepted
    logits[0, 1, 0] = big                      # pad token 0 has p=1 ...
    logits[0, 2, 5] = big
    L = jnp.asarray(logits)
    for k in keys:
        a, t = spec_sample_accept(L, jnp.asarray([[4, 0]]), jnp.asarray([1]),
                                  k, 1.0, 0)
        assert int(a[0]) == 1 and int(t[0]) == 0   # bonus from row k_eff=1
        # ... but it is the BONUS draw from row 1's p, not an acceptance:
        # a stopped at k_eff, exactly one draft committed


def _reset_engine(eng, seed):
    """Reuse a ServeEngine's jitted closures across seeded runs (fresh
    cache + counters; avoids per-seed recompilation in distribution tests).

    Mirrors the per-run state ServeEngine.__init__ sets up — if the engine
    grows new per-run state, add it here too (stale state would corrupt the
    empirical distributions these tests accumulate across runs)."""
    scfg = eng.scfg
    eng._sample_key = jax.random.PRNGKey(seed)
    eng._sample_step = 0
    eng.queue.clear()
    eng.slots = [None] * scfg.max_batch
    eng.cache = eng.model.init_cache(scfg.max_batch, eng._cache_len,
                                     dtype=eng.ccfg.resolved_kv_dtype)
    eng._staging = None
    eng._retired = []
    eng._rejected = 0
    eng._spec_ctx = [None] * scfg.max_batch
    eng.step_times = []
    eng._decode_tokens = 0
    eng._steps = 0
    eng._admission_waits = []
    eng._accepted_drafts = 0
    eng._spec_slot_steps = 0


def test_family_spec_sampled_engine_first_token_matches_exact_mixture(
        tiny_family_model):
    """Full-engine law check: over many seeds, the first DECODE-step token
    of a spec-sampled stream follows the exact mixture
    sum_t0 p0(t0) * p1(t1|t0) computed directly from the model — i.e. the
    engine's speculative sampling is distribution-equal to plain sampled
    decode end-to-end (admission draw included)."""
    fam, cfg, model, params = tiny_family_model
    T, top_k, v = 0.9, 0, cfg.vocab
    prompt = (np.asarray([1, 6, 2, 0, 3, 3, 7, 4]) % v).astype(np.int32)
    # exact reference: p0 over the admission token, p1 rows per t0
    pl, _ = model.prefill(params, {"tokens": jnp.asarray(prompt)[None, :]},
                          CCFG, max_len=64)
    p0 = _truncated_p(np.asarray(pl)[0, -1], T, top_k)
    exact = np.zeros(v)
    for t0 in range(v):
        ext = np.concatenate([prompt, [t0]]).astype(np.int32)
        pl1, _ = model.prefill(params, {"tokens": jnp.asarray(ext)[None, :]},
                               CCFG, max_len=64)
        exact += p0[t0] * _truncated_p(np.asarray(pl1)[0, -1], T, top_k)
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=1, max_len=64, batched=True,
                                  prefill_chunk=8, draft_len=2,
                                  temperature=T, top_k=top_k))
    assert eng.effective_mode == "spec-sampled"
    n = 150
    firsts = np.zeros(n, np.int64)
    for s in range(n):
        _reset_engine(eng, s)
        req = Request(uid=s, prompt=prompt, max_new_tokens=2)
        eng.submit(req)
        eng.run_until_drained(50)
        assert len(req.tokens_out) >= 2
        firsts[s] = req.tokens_out[1]
    emp = np.bincount(firsts, minlength=v) / n
    tv = 0.5 * np.abs(emp - exact).sum()
    assert tv < 0.2, (fam, tv, emp, exact)


def test_spec_sampled_full_rewind_is_identity_through_fused_step():
    """keep=0 after the FUSED sampled verify+accept dispatch restores the
    pre-verify cache bit-exactly — the sampled path's checkpoint is the
    same contract as the greedy one's (rewind under sampling)."""
    cfg, model, params = _load("codeqwen1.5-7b")
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True,
                                  prefill_chunk=8, draft_len=3,
                                  temperature=0.7, top_k=4))
    eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32) % cfg.vocab,
                       max_new_tokens=8))
    eng.step()                                  # admit + first spec step
    before = [np.asarray(x).copy() for x in jax.tree.leaves(eng.cache)]
    toks = jnp.zeros((2, 4), jnp.int32)
    keff = jnp.zeros((2,), jnp.int32)
    key = jax.random.fold_in(eng._sample_key, 99)
    _, _, cache2, ckpt = eng._spec_sample_fn(eng.params, toks, eng.cache,
                                             keff, key)
    rewound = eng._rewind_fn(cache2, ckpt, jnp.zeros((2,), jnp.int32))
    after = jax.tree.leaves(rewound)
    assert len(before) == len(after)
    for x, y in zip(before, after):
        np.testing.assert_array_equal(x, np.asarray(y))


def test_spec_sampled_eos_mid_acceptance_truncates_exactly():
    """Same seed with and without eos_id: the eos run must emit the
    identical stream up to and including the first eos and nothing after —
    post-eos accepted drafts are never exposed, exactly like greedy."""
    cfg, model, params = _load("codeqwen1.5-7b")
    scfg = ServeConfig(max_batch=1, max_len=64, batched=True, draft_len=4,
                       temperature=1.0, sample_seed=5)
    free, _ = _run(model, params, cfg, [8], scfg, max_new=8, seed=2)
    stream = free[0].tokens_out
    eos = stream[2]
    idx = stream.index(eos)
    out, _ = _run(model, params, cfg, [8],
                  dataclasses.replace(scfg, eos_id=eos), max_new=8, seed=2)
    assert out[0].tokens_out == stream[:idx + 1]


def test_spec_sampled_failover_carries_only_committed_tokens():
    """Replica death mid-sampled-speculation: the rebuilt prompt carries
    the original prompt + every COMMITTED token and nothing else. The
    survivor's continuation is differently-realized (fresh RNG counter —
    the documented caveat) but must be a valid, complete stream."""
    from repro.serve.elastic import ReplicaSet
    cfg, model, params = _load("codeqwen1.5-7b")
    scfg = ServeConfig(max_batch=1, max_len=64, batched=True, draft_len=3,
                       temperature=0.9, top_k=6, sample_seed=3)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(2)])
    victim = _requests(cfg, [8], max_new=8, seed=3)[0]
    rs.submit(victim)
    for _ in range(3):
        rs.step()
    emitted = list(victim.tokens_out)
    assert emitted, "victim must have committed tokens before the kill"
    killed_on = next(i for i, e in enumerate(rs.engines) if victim in e.slots)
    rs.kill_replica(killed_on)
    clone = rs.requeued[0]
    assert clone.prompt_carried == len(emitted)
    assert clone.prompt.tolist() == victim.prompt.tolist() + emitted
    rs.drain(max_steps=300)
    assert clone.done
    # carried history is immutable; the continuation completes the stream
    assert clone.tokens_out[:len(emitted)] == emitted
    assert len(clone.tokens_out) == 8
    assert all(0 <= t < cfg.vocab for t in clone.tokens_out)


def test_spec_metrics_report_acceptance():
    """Force full acceptance (zeroed head -> constant argmax-0 stream, and
    a prompt tail of zeros so the drafter's k_eff is 4 from the very first
    step — k_eff only covers REAL proposals, so without the warm tail the
    first few steps would honestly report short drafts) and check the
    acceptance accounting counts exactly the delivered drafts."""
    cfg, model, params = _load("codeqwen1.5-7b")
    params = dict(params)
    params["lm_head"] = jax.tree.map(jnp.zeros_like, params["lm_head"])
    rng = np.random.default_rng(0)
    pat = rng.integers(1, cfg.vocab, 4).astype(np.int32)
    prompt = np.concatenate([np.tile(pat, 2),
                             np.zeros(12, np.int32)]).astype(np.int32)
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=1, max_len=256, batched=True,
                                  prefill_chunk=8, draft_len=4))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=41))
    eng.run_until_drained(200)
    m = eng.metrics()
    assert m["spec"] and m["draft_len"] == 4
    assert m["effective_mode"] == "spec-greedy"
    # constant stream + warm drafter context: every step accepts all 4
    # real drafts (k_eff = 4 throughout)
    assert m["accepted_per_step"] == 4.0, m["accepted_per_step"]
    assert m["decode_tokens"] == 40         # first token comes from prefill
    # tokens delivered per slot-step = accepted drafts + the bonus token
    assert m["decode_tokens"] == m["draft_tokens_accepted"] + m["steps"]

"""Speculative decode: draft/verify/rewind across every registry family.

Speculation may only change SPEED, never output: every committed token is
the model's own greedy argmax given its prefix, so spec-on must be
token-exact with the plain (PR 2) single-token decode path — including at
ring-buffer wrap boundaries, through failover, and for eos / max_new /
context-limit retirement that fires mid-acceptance. A full rewind
(keep=0) must be the identity on the pre-verify cache for every cache
family: dense KV, MLA latents, ring buffers, and checkpointed recurrent
{conv, h, ssd} state.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.spec import ngram_propose

jax.config.update("jax_platform_name", "cpu")

CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)


def _load(arch):
    cfg, model = registry.load(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


@pytest.fixture(scope="module", params=sorted(registry.FAMILY_SMOKE), ids=str)
def family_model(request):
    return (request.param,) + _load(registry.FAMILY_SMOKE[request.param])


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, int(n)).astype(np.int32),
                    max_new_tokens=max_new) for i, n in enumerate(lens)]


def _run(model, params, cfg, lens, scfg, max_new=4, seed=0, max_steps=400):
    eng = ServeEngine(model, params, CCFG, scfg)
    reqs = _requests(cfg, lens, max_new=max_new, seed=seed)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps)
    return reqs, eng


# ---------------------------------------------------------------------------
# drafter (model-free prompt lookup)
# ---------------------------------------------------------------------------

def test_ngram_propose_matches_longest_recent_suffix():
    ctx = [1, 2, 3, 9, 1, 2, 3]
    # suffix 3-gram (1,2,3) occurred at 0; continuation is [9, 1, 2]
    assert ngram_propose(ctx, 3, 3).tolist() == [9, 1, 2]
    # most RECENT earlier occurrence wins
    ctx = [5, 7, 1, 5, 7, 2, 5, 7]
    assert ngram_propose(ctx, 1, 2).tolist() == [2]


def test_ngram_propose_falls_back_to_shorter_ngrams_and_misses():
    # no 3- or 2-gram match, but the 1-gram suffix [4] occurred earlier
    assert ngram_propose([4, 1, 2, 4], 2, 3).tolist() == [1, 2]
    # total miss -> zeros (a free, guaranteed-rejected guess)
    assert ngram_propose([1, 2, 3], 2, 3).tolist() == [0, 0]
    assert ngram_propose([7], 2, 3).tolist() == [0, 0]
    # continuation shorter than k is zero-padded
    assert ngram_propose([9, 3, 9], 3, 1).tolist() == [3, 9, 0]


# ---------------------------------------------------------------------------
# per-family token-exact parity (the tentpole guarantee)
# ---------------------------------------------------------------------------

def test_family_spec_equals_plain_decode_token_exact(family_model):
    """Spec-on (draft/verify/rewind) emits EXACTLY the plain greedy decode
    stream for every family — dense KV, MLA, ring + RG-LRU, conv + SSD."""
    fam, cfg, model, params = family_model
    lens = [8, 5, 12, 3]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8), max_new=6)
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=64, batched=True,
                                prefill_chunk=8, draft_len=3), max_new=6)
    assert eng.spec, f"{fam} must take the speculative path"
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (fam, a.uid, a.tokens_out, b.tokens_out)


def test_family_spec_with_budgeted_chunked_prefill_token_exact(family_model):
    """Speculation interleaved with budgeted chunked prefill admissions."""
    fam, cfg, model, params = family_model
    lens = [17, 8, 29]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8, token_budget=8), max_new=5)
    out, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8, token_budget=8, draft_len=4),
                  max_new=5)
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (fam, a.uid, a.tokens_out, b.tokens_out)


def test_family_spec_full_rewind_is_identity(family_model):
    """spec_rewind(keep=0) after a verify pass restores the pre-verify
    cache BIT-EXACTLY — rejected ring writes, recurrent checkpoints and
    position tables all roll back."""
    fam, cfg, model, params = family_model
    b = 3
    cache = model.init_cache(b, 32, dtype=jnp.float32)
    for i, n in enumerate([5, 8, 3]):       # slots at different positions
        toks = jnp.asarray(np.arange(n)[None, :] % cfg.vocab, jnp.int32)
        _, sub = model.prefill(params, {"tokens": toks}, CCFG, max_len=32)
        cache = model.write_cache(cache, sub, i)
    before = jax.tree.leaves(cache)
    chunk = jnp.asarray(np.arange(b * 4).reshape(b, 4) % cfg.vocab, jnp.int32)
    logits, after, ckpt = model.spec_verify(params, {"tokens": chunk}, cache, CCFG)
    assert logits.shape == (b, 4, cfg.vocab)
    rewound = model.spec_rewind(after, ckpt, jnp.zeros((b,), jnp.int32))
    restored = jax.tree.leaves(rewound)
    assert len(before) == len(restored)
    for x, y in zip(before, restored):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y)), fam


# ---------------------------------------------------------------------------
# ring-buffer wrap + draft clamping (griffin)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def griffin_w8():
    cfg, model = registry.load("recurrentgemma-2b", smoke=True)
    cfg = dataclasses.replace(cfg, window=8)
    model = registry.build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


def test_spec_rewind_at_ring_wrap_token_exact(griffin_w8):
    """Draft chunks that straddle the ring-buffer wrap: rejected writes
    clobber live in-window entries, so the rewind must RESTORE them (a pos
    rewind alone would silently drop attention context)."""
    cfg, model, params = griffin_w8
    lens = [23, 40, 9, 16]                  # prompts well past window=8
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8), max_new=8)
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=64, batched=True,
                                prefill_chunk=8, draft_len=5), max_new=8)
    assert eng.spec
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_spec_draft_len_clamped_to_ring(griffin_w8):
    """A (1+K) verify chunk must fit the ring like any extend chunk."""
    cfg, model, params = griffin_w8
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=1, max_len=64, batched=True,
                                  prefill_chunk=8, draft_len=32))
    assert eng.spec and eng._draft_len == 7   # window 8 -> chunk of 8


# ---------------------------------------------------------------------------
# retirement fires at exactly the right token mid-acceptance
# ---------------------------------------------------------------------------

def test_spec_eos_mid_acceptance_retires_exactly(family_model):
    """eos emitted inside an accepted run must truncate the stream at the
    same token plain decode stops at (never expose post-eos drafts)."""
    fam, cfg, model, params = family_model
    probe, _ = _run(model, params, cfg, [8],
                    ServeConfig(max_batch=1, max_len=64, batched=True),
                    max_new=6)
    eos = probe[0].tokens_out[2]            # a mid-stream token
    ref, _ = _run(model, params, cfg, [8],
                  ServeConfig(max_batch=1, max_len=64, batched=True,
                              eos_id=eos), max_new=6)
    out, _ = _run(model, params, cfg, [8],
                  ServeConfig(max_batch=1, max_len=64, batched=True,
                              eos_id=eos, draft_len=4), max_new=6)
    assert out[0].tokens_out == ref[0].tokens_out, fam
    assert out[0].tokens_out[-1] == eos or len(out[0].tokens_out) == 6


# ---------------------------------------------------------------------------
# failover mid-speculation
# ---------------------------------------------------------------------------

def test_spec_failover_carries_only_accepted_tokens(family_model):
    """Replica death mid-speculation: the rebuilt prompt contains the
    original prompt + every COMMITTED token and nothing else (unaccepted
    drafts never enter ``tokens_out``), and the survivor finishes the
    stream token-exact with an unkilled plain-decode run."""
    from repro.serve.elastic import ReplicaSet
    fam, cfg, model, params = family_model
    ref, _ = _run(model, params, cfg, [8],
                  ServeConfig(max_batch=1, max_len=64, batched=True),
                  max_new=8, seed=3)
    scfg = ServeConfig(max_batch=1, max_len=64, batched=True, draft_len=3)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(2)])
    victim = _requests(cfg, [8], max_new=8, seed=3)[0]
    rs.submit(victim)
    for _ in range(3):                      # prefill + a couple of spec steps
        rs.step()
    emitted = list(victim.tokens_out)
    killed_on = next(i for i, e in enumerate(rs.engines) if victim in e.slots)
    rs.kill_replica(killed_on)
    clone = rs.requeued[0]
    # the carry invariant: prompt grew by exactly the committed tokens
    assert clone.prompt_carried == len(emitted)
    assert clone.prompt.tolist() == victim.prompt.tolist() + emitted
    rs.drain(max_steps=200)
    assert clone.done
    assert clone.tokens_out == ref[0].tokens_out, (fam, clone.tokens_out,
                                                   ref[0].tokens_out)


# ---------------------------------------------------------------------------
# degeneration + gating
# ---------------------------------------------------------------------------

def test_draft_len_zero_degenerates_to_plain_batched(family_model):
    """draft_len=0 must be the PR 2 path: no spec attributes consulted, one
    decode dispatch per step."""
    fam, cfg, model, params = family_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True,
                                  draft_len=0))
    assert eng.batched and not eng.spec
    for r in _requests(cfg, [8, 8]):
        eng.submit(r)
    calls = []
    inner = eng._decode_fn
    eng._decode_fn = lambda *a: calls.append(1) or inner(*a)
    eng.step()
    assert len(calls) == 1


def test_sampling_disables_speculation():
    """Speculation is greedy-only: temperature > 0 falls back to the
    (on-device) sampled batched path, which must still be seed-deterministic."""
    cfg, model, params = _load("codeqwen1.5-7b")
    scfg = ServeConfig(max_batch=2, max_len=64, batched=True, draft_len=4,
                       temperature=0.9, top_k=5, sample_seed=11)
    a, eng = _run(model, params, cfg, [8, 5], scfg, max_new=5)
    assert not eng.spec
    b_, _ = _run(model, params, cfg, [8, 5], scfg, max_new=5)
    for ra, rb in zip(a, b_):
        assert ra.tokens_out == rb.tokens_out
        assert all(0 <= t < cfg.vocab for t in ra.tokens_out)


def test_spec_metrics_report_acceptance():
    """Force full acceptance (zeroed head -> constant argmax, so the n-gram
    drafter predicts the stream perfectly after warmup) and check the
    acceptance accounting actually counts delivered drafts."""
    cfg, model, params = _load("codeqwen1.5-7b")
    params = dict(params)
    params["lm_head"] = jax.tree.map(jnp.zeros_like, params["lm_head"])
    rng = np.random.default_rng(0)
    pat = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=1, max_len=256, batched=True,
                                  prefill_chunk=8, draft_len=4))
    eng.submit(Request(uid=0, prompt=np.tile(pat, 5), max_new_tokens=41))
    eng.run_until_drained(200)
    m = eng.metrics()
    assert m["spec"] and m["draft_len"] == 4
    # constant stream: every step after the first accepts all 4 drafts (the
    # very first draft may miss before a 0 enters the context)
    assert m["accepted_per_step"] > 3.0, m["accepted_per_step"]
    assert m["decode_tokens"] == 40         # first token comes from prefill
    # tokens delivered per slot-step = accepted drafts + the bonus token
    assert m["decode_tokens"] == m["draft_tokens_accepted"] + m["steps"]

"""Substrate tests: data pipeline, optimizer, checkpoint/restart, gradient
compression, CREST, sharding specs, serve engine + elastic failover."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, strategies as st

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_host_sharded():
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    c1 = SyntheticCorpus(DataConfig(vocab=128, seq_len=32, global_batch=8))
    c2 = SyntheticCorpus(DataConfig(vocab=128, seq_len=32, global_batch=8))
    b1, b2 = c1.batch_at(7), c2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # different hosts produce different shards
    ch = SyntheticCorpus(DataConfig(vocab=128, seq_len=32, global_batch=8,
                                    host_id=1, n_hosts=2))
    assert ch.local_batch == 4
    assert not np.array_equal(ch.batch_at(7)["tokens"], b1["tokens"][:4])


def test_data_has_learnable_structure():
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    c = SyntheticCorpus(DataConfig(vocab=64, seq_len=256, global_batch=4))
    b = c.batch_at(0)
    # Markov structure: successor entropy given token < unigram entropy
    toks = b["tokens"].reshape(-1)
    # top-1 bigram predictability must beat uniform chance by a wide margin
    pairs = {}
    for a, bb in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(a), []).append(int(bb))
    hits = tot = 0
    for a, succ in pairs.items():
        if len(succ) < 4:
            continue
        vals, counts = np.unique(succ, return_counts=True)
        hits += counts.max()
        tot += len(succ)
    assert hits / tot > 0.15, "corpus has no learnable bigram structure"


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    from repro.optim.adamw import AdamW
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1, decay_steps=200)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_weight_decay_mask():
    from repro.optim.adamw import AdamW
    opt = AdamW(lr=1e-2, weight_decay=1.0, warmup_steps=1)
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    state = opt.init(params)
    zero_grads = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = opt.update(zero_grads, state, params)
    assert float(p2["w"].max()) < 1.0        # decayed (ndim >= 2)
    assert float(p2["scale"].min()) == 1.0   # not decayed (1-D)


# ---------------------------------------------------------------------------
# checkpoint / restart
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest(tmp_path):
    from repro.train import checkpoint as ckpt
    tree = {"a": jnp.arange(6).reshape(2, 3), "n": {"b": jnp.float32(3.5)},
            "l": [jnp.ones(2), jnp.zeros(3)]}
    ckpt.save(tree, str(tmp_path), 10, extra={"data_step": 10})
    ckpt.save(jax.tree.map(lambda x: x + 1, tree), str(tmp_path), 20,
              extra={"data_step": 20})
    assert ckpt.latest_step(str(tmp_path)) == 20
    restored, extra = ckpt.restore(tree, str(tmp_path))
    assert extra["data_step"] == 20
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]) + 1)


def test_checkpoint_async_and_atomic(tmp_path):
    from repro.train import checkpoint as ckpt
    tree = {"w": jnp.ones((32, 32))}
    t = ckpt.save(tree, str(tmp_path), 5, async_=True)
    t.join()
    assert ckpt.latest_step(str(tmp_path)) == 5
    # a stale .tmp dir must not be considered a checkpoint
    os.makedirs(tmp_path / "step_99.tmp", exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_train_restart_bit_identical(tmp_path):
    """Fault-tolerance invariant: save at step k, 'crash', restore, continue
    => identical loss trajectory to an uninterrupted run."""
    from repro.core.cascade import CascadeConfig
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.models import registry
    from repro.optim.adamw import AdamW
    from repro.train import checkpoint as ckpt
    from repro.train import loop as train_loop

    cfg, model = registry.load("olmoe-1b-7b", smoke=True)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    opt = AdamW(lr=1e-3, warmup_steps=2, decay_steps=10)
    data = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2))
    step_fn = jax.jit(train_loop.make_train_step(model, ccfg, opt, remat=False))

    state = train_loop.init_state(model, ccfg, opt)
    losses_a = []
    for i in range(6):
        if i == 3:
            ckpt.save(state, str(tmp_path), i, extra={"data_step": i})
        state, m = step_fn(state, jax.tree.map(jnp.asarray, data.batch_at(i)))
        losses_a.append(float(m["loss"]))

    # crash + restore at step 3
    state_b = train_loop.init_state(model, ccfg, opt)
    state_b, extra = ckpt.restore(state_b, str(tmp_path))
    losses_b = []
    for i in range(int(extra["data_step"]), 6):
        state_b, m = step_fn(state_b, jax.tree.map(jnp.asarray, data.batch_at(i)))
        losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-6)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_grad_compression_error_feedback_unbiased(seed):
    """With error feedback, the accumulated compressed sum converges to the
    true sum: residual stays bounded by one quantization step."""
    from repro.optim import grad_compression as gc
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (64,))
    r = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for i in range(8):
        q, scale, r = gc.compress(g, r)
        total = total + gc.decompress(q, scale)
    # sum of 8 compressed reps ~ 8*g, residual bounded
    np.testing.assert_allclose(np.asarray(total + r), np.asarray(8 * g), rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(r).max()) <= float(scale) + 1e-6


def test_grad_compression_allreduce_shardmap():
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim import grad_compression as gc

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 8))}
    r = gc.init_residuals(g)

    @partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
             check_rep=False)
    def f(g, r):
        return gc.allreduce_compressed(g, r, "data")

    out, new_r = f(g, r)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# CREST
# ---------------------------------------------------------------------------

def test_crest_detects_and_repairs_all_faults():
    from repro.core import crest
    cfg = crest.CrestConfig(n_spares=4, threshold=2)
    n, k, m = 32, 16, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n))
    fault = crest.inject_column_faults(jax.random.PRNGKey(1), n, 3)
    state = crest.crest_init(n, cfg)
    step = jax.jit(lambda x, s: crest.crest_matmul(x, w, s, cfg, fault))
    for i in range(40):
        x = jax.random.normal(jax.random.PRNGKey(100 + i), (m, k))
        y, state = step(x, state)
    stats = crest.coverage_stats(state, fault)
    assert stats["detected"] == 3 and stats["false_positives"] == 0
    # post-repair output matches the clean matmul everywhere
    x = jax.random.normal(jax.random.PRNGKey(999), (m, k))
    y, _ = step(x, state)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-4)


def test_crest_healthy_path_is_exact_and_stateless():
    from repro.core import crest
    cfg = crest.CrestConfig(n_spares=2, threshold=3)
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    state = crest.crest_init(16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    y, s2 = crest.crest_matmul(x, w, state, cfg, None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-5)
    assert int(s2.confirmed_faults.sum()) == 0
    assert int(s2.n_repaired) == 0


def test_crest_transient_errors_filtered():
    """A fault that appears once (cosmic ray) then disappears must NOT be
    confirmed (threshold consecutive-mismatch filter, paper Section 20.2)."""
    from repro.core import crest
    cfg = crest.CrestConfig(n_spares=16, threshold=3)  # test all cols each step
    n, k = 16, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n))
    state = crest.crest_init(n, cfg)
    transient = jnp.zeros((n,), bool).at[5].set(True)
    for i in range(6):
        x = jax.random.normal(jax.random.PRNGKey(i), (4, k))
        mask = transient if i == 2 else None  # single-step glitch
        _, state = jax.jit(lambda x, s, fm: crest.crest_matmul(x, w, s, cfg, fm),
                           static_argnums=())(x, state, mask) if False else \
            crest.crest_matmul(x, w, state, cfg, mask)
    assert int(state.confirmed_faults.sum()) == 0


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------

def test_param_specs_cascade_never_shards_contraction():
    """CASCADE policy invariant (the paper's core claim): no weight is sharded
    on its contraction dim => no partial-sum all-reduce can exist."""
    from jax.sharding import PartitionSpec as P
    from repro.core.cascade import CascadeConfig
    from repro.distributed import sharding as shd
    from repro.models import registry

    for arch in ["qwen2.5-32b", "deepseek-v2-236b", "mamba2-370m"]:
        cfg, model = registry.load(arch, smoke=True)
        ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
        pshape = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), ccfg))
        specs = shd.param_specs(pshape, "cascade")
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        for path, spec in flat:
            names = [str(getattr(k, "key", "")) for k in path]
            if names[-1] == "w" and "model" in str(spec):
                if spec[-1] in ("model", ("model",)):
                    # column-parallel: model on output dim only
                    assert all(s != "model" for s in spec[:-1]), (names, spec)
                else:
                    # expert-parallel: model on the E dim; both matmul dims
                    # (contraction K and output N) stay local
                    assert spec[-3] == "model", (names, spec)
                    assert spec[-1] is None and spec[-2] is None, (names, spec)


def test_param_specs_megatron_row_shards_contraction():
    from repro.core.cascade import CascadeConfig
    from repro.distributed import sharding as shd
    from repro.models import registry
    cfg, model = registry.load("qwen2.5-32b", smoke=True)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    pshape = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), ccfg))
    specs = shd.param_specs(pshape, "megatron")
    wo = specs["layers"]["attn"]["wo"]["w"]
    assert wo[-2] == "model" and wo[-1] is None  # row-parallel


# ---------------------------------------------------------------------------
# serve engine + elastic
# ---------------------------------------------------------------------------

def _tiny_engine(max_batch=2, n=None):
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg, model = registry.load("codeqwen1.5-7b", smoke=True)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), ccfg)
    eng = ServeEngine(model, params, ccfg,
                      ServeConfig(max_batch=max_batch, max_len=64))
    return cfg, eng


def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request
    cfg, eng = _tiny_engine(max_batch=2)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    for _ in range(100):
        eng.step()
        if not eng.busy():
            break
    assert all(r.done for r in reqs)
    assert all(len(r.tokens_out) == 4 for r in reqs)


def test_elastic_replica_failure_requeues_and_completes():
    from repro.serve.elastic import ReplicaSet
    from repro.serve.engine import Request
    cfg, e1 = _tiny_engine(max_batch=2)
    _, e2 = _tiny_engine(max_batch=2)
    rs = ReplicaSet([e1, e2])
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=6) for i in range(6)]
    for r in reqs:
        rs.submit(r)
    rs.step()
    rs.kill_replica(0)  # hard failure with work in flight
    rs.drain(max_steps=200)
    # every uid finished somewhere (original or re-queued failover clone)
    done_uids = {r.uid for r in reqs if r.done} | {r.uid for r in rs.requeued if r.done}
    assert done_uids == {r.uid for r in reqs}, done_uids
    assert not rs.health[0].alive and rs.health[1].alive


def test_serve_engine_crest_bist_detects_injected_faults():
    """CREST as POST/BIST inside the serving engine (paper Section 20.6):
    probe waves on the lm_head weight detect injected column defects while
    requests keep flowing."""
    import jax
    from repro.core import crest as crest_mod
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.serve.engine import Request, ServeConfig, ServeEngine
    cfg, model = registry.load("codeqwen1.5-7b", smoke=True)
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0), ccfg)
    scfg = ServeConfig(max_batch=2, max_len=48, crest_enabled=True, crest_every=1,
                       crest_cfg=crest_mod.CrestConfig(n_spares=8, threshold=2))
    eng = ServeEngine(model, params, ccfg, scfg)
    eng.fault_mask = crest_mod.inject_column_faults(jax.random.PRNGKey(7), cfg.vocab, 3)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                           max_new_tokens=16))
    for _ in range(200):
        eng.step()
        if not eng.busy():
            break
    # the BIST cycle keeps running between traffic bursts (paper: stress
    # testing in idle periods, Section 20.5)
    for _ in range(3 * cfg.vocab // scfg.crest_cfg.n_spares):
        eng._steps += 1
        eng._crest_probe()
    rep = eng.crest_report()
    assert rep["confirmed_faults"] >= 3, rep
    assert rep["repaired"] >= 3, rep


def test_moe_ep_shardmap_matches_jit_dispatch_single_device():
    """The shard_map expert-parallel MoE must equal the jit capacity
    dispatch on a degenerate (1,1) mesh (plumbing + math identity)."""
    import dataclasses
    import jax
    from repro.core.cascade import CascadeConfig
    from repro.models import registry
    from repro.models.moe import moe_ffn_apply, moe_ffn_init
    from repro.models.moe_shardmap import moe_ffn_apply_ep

    cfg, _ = registry.load("olmoe-1b-7b", smoke=True)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=50.0)  # no drops
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
    params = moe_ffn_init(jax.random.PRNGKey(0), cfg, ccfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    y_jit = moe_ffn_apply(params, x, cfg, ccfg)
    y_ep = moe_ffn_apply_ep(params, x, cfg, ccfg, mesh)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_jit),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.slow
def test_moe_ep_shardmap_matches_jit_multirank_subprocess():
    """EP correctness with real expert sharding: 8 virtual devices,
    mesh (2, 4): tokens over 2 data shards, experts over 4 model ranks."""
    import subprocess, sys, os
    code = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.models.moe import moe_ffn_apply, moe_ffn_init
from repro.models.moe_shardmap import moe_ffn_apply_ep
cfg, _ = registry.load("olmoe-1b-7b", smoke=True)
cfg = dataclasses.replace(cfg, moe_capacity_factor=50.0)
ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32)
params = moe_ffn_init(jax.random.PRNGKey(0), cfg, ccfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
y_jit = moe_ffn_apply(params, x, cfg, ccfg)
with mesh:
    y_ep = moe_ffn_apply_ep(params, x, cfg, ccfg, mesh)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_jit), atol=1e-4, rtol=1e-4)
print("EP-MULTIRANK-OK")
'''
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run([sys.executable, "-c", code], cwd=repo,
                          env={**os.environ, "PYTHONPATH": os.path.join(repo, "src")},
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0 and "EP-MULTIRANK-OK" in proc.stdout, \
        proc.stdout[-500:] + proc.stderr[-500:]

"""Import ``given/settings/strategies`` from here instead of hypothesis.

When hypothesis is installed this is a pass-through. When it is not (the
tier-1 CPU image ships without it), property tests are individually skipped
instead of breaking collection of the whole file — plain tests in the same
module keep running.
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """Stands in for strategy objects; never executed, only composed."""

        def __getattr__(self, _name):
            return _AnyStrategy()

        def __call__(self, *_a, **_k):
            return _AnyStrategy()

    strategies = _AnyStrategy()

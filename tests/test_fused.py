"""Fused FP4 decode path: token-exactness, downgrades, mode reporting.

The fused engine (``ServeConfig.fused``) routes every linear through the
packed-FP4 Pallas matmul and single-token attention through the decode
kernel. In interpret mode (CPU/CI) the kernels run their exact paths, so
the contract is TOKEN-EXACT parity with the jnp serve_fp4 engine — greedy,
speculative, and sampled — not allclose.
"""
import jax

# sampled parity compares engines constructed in one process: the flag must
# flip BEFORE any params are drawn, or the first engine's construction
# re-bases every later realization (see the engine's construction warning)
jax.config.update("jax_threefry_partitionable", True)
jax.config.update("jax_platform_name", "cpu")

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cascade
from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.engine import Request, ServeConfig, ServeEngine

CCFG_TRAIN = CascadeConfig(mode="train", compute_dtype=jnp.float32)
CCFG_FP4 = CascadeConfig(mode="serve_fp4", compute_dtype=jnp.float32)


def _fp4_load(arch):
    cfg, model = registry.load(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG_TRAIN)
    return cfg, model, cascade.tree_to_serve_fp4(params, CCFG_FP4)


@pytest.fixture(scope="module")
def fp4_transformer():
    return _fp4_load("codeqwen1.5-7b")


def _serve(model, params, cfg, *, fused, ccfg=CCFG_FP4, draft_len=0,
           temperature=0.0, batched=True, max_new=10, n_req=3):
    scfg = ServeConfig(max_batch=2, max_len=40, fused=fused, batched=batched,
                       draft_len=draft_len, temperature=temperature, top_k=8)
    eng = ServeEngine(model, params, ccfg, scfg)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
                    max_new_tokens=max_new) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, [list(r.tokens_out) for r in reqs]


# ---------------------------------------------------------------------------
# token-exact parity, per registry family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(registry.FAMILY_SMOKE))
def test_fused_greedy_token_exact(family):
    """Every serving family emits exactly the jnp engine's greedy tokens
    when decode routes through the kernels."""
    cfg, model, params = _fp4_load(registry.FAMILY_SMOKE[family])
    _, ref = _serve(model, params, cfg, fused=False)
    eng, out = _serve(model, params, cfg, fused=True)
    assert eng.fused and eng.effective_mode == "batched-greedy-fused"
    assert not eng.downgrades
    assert out == ref


def test_fused_spec_token_exact(fp4_transformer):
    """Speculative decode (draft + verify + rewind) through the fused
    dispatch commits exactly the jnp spec engine's tokens."""
    cfg, model, params = fp4_transformer
    _, ref = _serve(model, params, cfg, fused=False, draft_len=3)
    eng, out = _serve(model, params, cfg, fused=True, draft_len=3)
    assert eng.effective_mode == "spec-greedy-fused"
    assert out == ref


def test_fused_sampled_token_exact(fp4_transformer):
    """Seeded sampling: bit-identical logits + the same fold_in draw order
    means identical realizations, so sampled streams match token-for-token."""
    cfg, model, params = fp4_transformer
    _, ref = _serve(model, params, cfg, fused=False, temperature=0.7)
    eng, out = _serve(model, params, cfg, fused=True, temperature=0.7)
    assert eng.effective_mode == "batched-sampled-fused"
    assert out == ref


def test_fused_spec_sampled_token_exact(fp4_transformer):
    """Speculative SAMPLING (rejection resampling) through the fused verify
    dispatch stays realization-exact with the jnp engine."""
    cfg, model, params = fp4_transformer
    _, ref = _serve(model, params, cfg, fused=False, draft_len=3,
                    temperature=0.7)
    eng, out = _serve(model, params, cfg, fused=True, draft_len=3,
                      temperature=0.7)
    assert eng.effective_mode == "spec-sampled-fused"
    assert out == ref


# ---------------------------------------------------------------------------
# downgrades: never silently run a different path than reported
# ---------------------------------------------------------------------------

def test_fused_downgrades_without_fp4_params():
    """fused + train-format params can't take the kernel path: the engine
    must record the downgrade and report an un-suffixed effective_mode."""
    cfg, model = registry.load("codeqwen1.5-7b", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG_TRAIN)
    with pytest.warns(RuntimeWarning, match="fused decode requested"):
        eng, _ = _serve(model, params, cfg, fused=True, ccfg=CCFG_TRAIN,
                        max_new=2, n_req=1)
    assert not eng.fused
    assert eng.effective_mode == "batched-greedy"
    assert any("fused" in d for d in eng.downgrades)


def test_fused_downgrades_on_slotwise_path(fp4_transformer):
    cfg, model, params = fp4_transformer
    with pytest.warns(RuntimeWarning, match="fused decode requested"):
        eng, _ = _serve(model, params, cfg, fused=True, batched=False,
                        max_new=2, n_req=1)
    assert not eng.fused
    assert not eng.effective_mode.endswith("-fused")


def test_fused_ssm_dispatches_decode_kernel(monkeypatch):
    """The fused SSD decode path actually routes through ops.ssd_decode
    (the scan kernel at s = chunk = 1 carrying the slot states), not the
    eager jnp recurrence — and the jnp engine never touches the kernel.
    Token parity for the ssm family is covered by the parametrized greedy
    test above; this pins the DISPATCH."""
    from repro.kernels import ops

    cfg, model, params = _fp4_load(registry.FAMILY_SMOKE["ssm"])
    calls = {"n": 0}
    real = ops.ssd_decode

    def spy(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(ops, "ssd_decode", spy)
    eng, _ = _serve(model, params, cfg, fused=True, max_new=4, n_req=1)
    assert eng.fused and not eng.downgrades
    assert calls["n"] > 0, "fused ssm decode never dispatched the kernel"
    calls["n"] = 0
    _serve(model, params, cfg, fused=False, max_new=4, n_req=1)
    assert calls["n"] == 0, "jnp engine must not touch the kernel path"


def test_fused_metrics_flag(fp4_transformer):
    cfg, model, params = fp4_transformer
    eng, _ = _serve(model, params, cfg, fused=True, max_new=2, n_req=1)
    m = eng.metrics()
    assert m["fused"] is True
    assert m["effective_mode"].endswith("-fused")

"""Mesh-native serving: sharded-vs-unsharded token-exactness and the
CASCADE zero-partial-sum-all-reduce invariant, on a forced 8-device host
mesh.

These tests need ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in
the environment BEFORE jax initializes (the CI ``mesh-serving`` leg sets
it; plain tier-1 runs skip). What they pin down:

* params placed by ``param_specs`` (cascade AND megatron) + caches sharded
  on their probe-discovered slot axis over ``data`` produce EXACTLY the
  tokens of the PR-3 single-device engine — greedy, speculative, and
  failover schedules, for all four registry families;
* the cascade-policy decode step (and spec-verify pass) compiles to HLO
  with ZERO partial-sum all-reduce — the paper's Sections 2.2/13.5 claim
  as an executable assertion — while the megatron baseline's decode step
  demonstrably contains them;
* failover is shard-aware in both directions: a sharded replica dying onto
  an unsharded survivor (and the reverse) never changes a token.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.cascade import CascadeConfig
from repro.launch.mesh import make_mesh, parse_mesh_shape
from repro.models import registry
from repro.serve.engine import Request, ServeConfig, ServeEngine

CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)
LENS = [8, 5, 12, 3, 20, 9]


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="module", params=sorted(registry.FAMILY_SMOKE), ids=str)
def family_model(request):
    cfg, model = registry.load(registry.FAMILY_SMOKE[request.param], smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return request.param, cfg, model, params


def _requests(cfg, lens, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, int(n)).astype(np.int32),
                    max_new_tokens=max_new) for i, n in enumerate(lens)]


def _run(model, params, cfg, lens, scfg, mesh=None, max_new=6, seed=0):
    eng = ServeEngine(model, params, CCFG, scfg, mesh=mesh)
    reqs = _requests(cfg, lens, max_new=max_new, seed=seed)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(400)
    return [r.tokens_out for r in reqs], eng


def _scfg(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    return ServeConfig(**kw)


# ---------------------------------------------------------------------------
# token-exact parity: greedy / budgeted / spec
# ---------------------------------------------------------------------------

def test_family_sharded_greedy_token_exact(family_model, mesh):
    """Cascade-sharded decode (params column-parallel, cache slot axis over
    data) emits exactly the single-device tokens for every family."""
    fam, cfg, model, params = family_model
    ref, _ = _run(model, params, cfg, LENS, _scfg())
    out, eng = _run(model, params, cfg, LENS, _scfg(), mesh=mesh)
    assert eng.mesh is not None and eng.batched
    assert ref == out, (fam, ref, out)


def test_family_sharded_budgeted_chunked_token_exact(family_model, mesh):
    """Chunked prefill under a token budget — the admission interleaving —
    stays token-exact through the sharded extend path."""
    fam, cfg, model, params = family_model
    lens = [17, 8, 29, 4]
    ref, _ = _run(model, params, cfg, lens, _scfg(max_batch=2))
    out, _ = _run(model, params, cfg, lens,
                  _scfg(max_batch=2, token_budget=8), mesh=mesh)
    assert ref == out, (fam, ref, out)


def test_family_sharded_spec_token_exact(family_model, mesh):
    """Speculative decode on the mesh: drafts, ONE sharded verify pass and
    per-family sharded rewinds commit exactly the plain greedy stream."""
    fam, cfg, model, params = family_model
    ref, _ = _run(model, params, cfg, LENS, _scfg())
    out, eng = _run(model, params, cfg, LENS, _scfg(draft_len=4), mesh=mesh)
    assert eng.spec, f"{fam} must take the speculative path"
    assert ref == out, (fam, ref, out)


def test_sharded_params_and_cache_actually_sharded(family_model, mesh):
    """The mesh engine must not degenerate to replication: at least one
    param leaf is model-sharded and at least one cache leaf is data-sharded
    (slot axis), for every family."""
    fam, cfg, model, params = family_model
    eng = ServeEngine(model, params, CCFG, _scfg(), mesh=mesh)

    def sharded_over(tree, axis):
        found = []
        for leaf in jax.tree.leaves(tree):
            spec = getattr(getattr(leaf, "sharding", None), "spec", None)
            if spec is not None and any(
                    axis in (p if isinstance(p, tuple) else (p,))
                    for p in spec if p is not None):
                found.append(leaf)
        return found

    assert sharded_over(eng.params, "model"), f"{fam}: no model-sharded param"
    assert sharded_over(eng.cache, "data"), f"{fam}: no data-sharded cache leaf"


# ---------------------------------------------------------------------------
# the paper's interconnect claim, as HLO
# ---------------------------------------------------------------------------

def test_family_cascade_decode_step_has_zero_partial_sum_allreduce(
        family_model, mesh):
    """Sections 2.2/13.5 executable: the compiled cascade decode step over
    the sharded grid contains NO all-reduce with an add combiner."""
    from benchmarks import hlo_analysis
    fam, cfg, model, params = family_model
    eng = ServeEngine(model, params, CCFG, _scfg(), mesh=mesh)
    ar = hlo_analysis.partial_sum_allreduces(eng.decode_step_hlo())
    assert ar["count"] == 0, (fam, ar["ops"])


def test_family_cascade_verify_pass_has_zero_partial_sum_allreduce(
        family_model, mesh):
    """The speculative (1+K)-position verify pass obeys the same invariant
    — speculation does not reintroduce partial-sum traffic."""
    from benchmarks import hlo_analysis
    fam, cfg, model, params = family_model
    eng = ServeEngine(model, params, CCFG, _scfg(draft_len=4), mesh=mesh)
    ar = hlo_analysis.partial_sum_allreduces(eng.decode_step_hlo("verify"))
    assert ar["count"] == 0, (fam, ar["ops"])


def test_megatron_decode_step_contains_partial_sum_allreduce(mesh):
    """The contrast that makes the zero meaningful: the row+column baseline
    DOES emit add-combiner all-reduces in the same decode step — and still
    serves (tokens flow, streams finish)."""
    from benchmarks import hlo_analysis
    cfg, model = registry.load(registry.FAMILY_SMOKE["transformer"], smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    out, eng = _run(model, params, cfg, [8, 5], _scfg(tp_policy="megatron"),
                    mesh=mesh)
    assert all(len(t) == 6 for t in out)
    ar = hlo_analysis.partial_sum_allreduces(eng.decode_step_hlo())
    assert ar["count"] > 0, "megatron baseline should partial-sum all-reduce"


# ---------------------------------------------------------------------------
# shard-aware failover
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dying", ["sharded", "plain"])
def test_family_failover_across_mesh_boundary_token_exact(
        family_model, mesh, dying):
    """Kill a sharded replica onto an unsharded survivor (and the reverse):
    the host-side token carry admits into the survivor's own placement and
    the stream is token-exact with an undisturbed single-engine run."""
    from repro.serve.elastic import ReplicaSet
    fam, cfg, model, params = family_model
    want, _ = _run(model, params, cfg, [8, 12], _scfg(max_batch=2), max_new=10)

    e0 = ServeEngine(model, params, CCFG, _scfg(max_batch=2),
                     mesh=mesh if dying == "sharded" else None)
    e1 = ServeEngine(model, params, CCFG, _scfg(max_batch=2),
                     mesh=None if dying == "sharded" else mesh)
    rs = ReplicaSet([e0, e1])
    victims = _requests(cfg, [8, 12], max_new=10)
    for v in victims:
        rs.engines[0].submit(v)
    for _ in range(3):
        rs.step()
    assert any(len(v.tokens_out) > 0 for v in victims)
    rs.kill_replica(0)
    rs.drain(400)
    clones = {c.uid: c.tokens_out for c in rs.requeued}
    got = [clones.get(v.uid, v.tokens_out) for v in victims]
    assert got == want, (fam, dying, got, want)


# ---------------------------------------------------------------------------
# placement plumbing
# ---------------------------------------------------------------------------

def test_cache_pspecs_puts_data_on_probed_slot_axis(family_model, mesh):
    """Every cache leaf's spec carries 'data' exactly at its probed slot
    axis (or is replicated when the slot extent doesn't divide)."""
    fam, cfg, model, params = family_model
    cache = model.init_cache(4, 32, dtype=jnp.float32)
    specs = model.cache_pspecs(cache, mesh)
    axes = model._slot_spec()
    from jax.sharding import PartitionSpec as P
    flat_a = jax.tree.leaves(axes)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_c = jax.tree.leaves(cache)
    assert len(flat_a) == len(flat_s) == len(flat_c)
    for ax, spec, leaf in zip(flat_a, flat_s, flat_c):
        parts = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        for i, p in enumerate(parts):
            if i == ax and leaf.shape[ax] % 4 == 0:
                assert p == "data", (fam, ax, spec, leaf.shape)
            else:
                assert p is None or i == ax, (fam, ax, spec, leaf.shape)


def test_filter_divisible_drops_odd_dims(mesh):
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd
    tree = {"a": jnp.zeros((6, 3)), "b": jnp.zeros((8, 4))}
    specs = {"a": P("data", "model"), "b": P("data", "model")}
    out = shd.filter_divisible(specs, tree, mesh)
    assert out["a"] == P(None, None)          # 6 % 4 != 0, 3 % 2 != 0
    assert out["b"] == P("data", "model")


def test_parse_mesh_shape():
    assert parse_mesh_shape("4x2") == (4, 2)
    d, m = parse_mesh_shape("auto")
    assert d * m == len(jax.devices()) and m >= 1


def test_mesh_rejects_slotwise_engine(family_model, mesh):
    fam, cfg, model, params = family_model
    with pytest.raises(ValueError):
        ServeEngine(model, params, CCFG, _scfg(batched=False), mesh=mesh)


def test_sharded_sampling_deterministic_and_on_device(mesh):
    """Seeded sampling runs on the sharded grid too: same seed + schedule
    => identical tokens, drawn from the one shared fold_in counter."""
    cfg, model = registry.load(registry.FAMILY_SMOKE["transformer"], smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    scfg = _scfg(temperature=1.0, top_k=5, sample_seed=7)
    a, _ = _run(model, params, cfg, [8, 5], scfg, mesh=mesh)
    b, _ = _run(model, params, cfg, [8, 5], scfg, mesh=mesh)
    assert a == b
    assert all(0 <= t < cfg.vocab for row in a for t in row)


def test_family_sampled_spec_sharded_token_parity(family_model, mesh):
    """Speculative SAMPLING on the mesh: the sharded spec-sampled engine
    emits exactly the unsharded spec-sampled engine's tokens for the same
    seed + schedule (contractions never split and the K+1 logit rows are
    pinned replicated before the acceptance draws, so the on-device
    uniforms/Gumbel see bitwise-identical inputs) — token parity implies
    the distribution parity the tentpole requires, realization included."""
    fam, cfg, model, params = family_model
    scfg = _scfg(draft_len=3, temperature=0.9, top_k=6, sample_seed=13)
    ref, e0 = _run(model, params, cfg, LENS, scfg)
    out, eng = _run(model, params, cfg, LENS, scfg, mesh=mesh)
    assert e0.effective_mode == eng.effective_mode == "spec-sampled"
    assert ref == out, (fam, ref, out)


def test_sampled_spec_verify_step_has_zero_partial_sum_allreduce(mesh):
    """The FUSED sampled verify+accept/resample step — what a temperature>0
    spec engine actually dispatches, and what decode_step_hlo('verify')
    lowers when sampling is on — obeys the cascade zero-AR invariant: the
    K+1 logit rows are pinned replicated before top-k/softmax/Gumbel, so
    speculative sampling adds no partial-sum traffic."""
    from benchmarks import hlo_analysis
    cfg, model = registry.load(registry.FAMILY_SMOKE["transformer"], smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    eng = ServeEngine(model, params, CCFG,
                      _scfg(draft_len=4, temperature=0.8, top_k=5), mesh=mesh)
    assert eng.effective_mode == "spec-sampled"
    ar = hlo_analysis.partial_sum_allreduces(eng.decode_step_hlo("verify"))
    assert ar["count"] == 0, ar["ops"]


def test_audit_engine_cascade_mesh_transformer_clean(mesh):
    """The full serving-contract auditor over every closure of a sharded
    cascade engine: zero gating findings (donation honored per shard, no
    host transfers, no partial-sum ARs outside the exempted chunked
    prefill)."""
    from repro.analysis import contract
    from repro.analysis.findings import gating
    cfg, model = registry.load(registry.FAMILY_SMOKE["transformer"], smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    eng = ServeEngine(model, params, CCFG,
                      _scfg(draft_len=2, temperature=0.7), mesh=mesh)
    res = contract.audit_engine(eng)
    assert gating(res["findings"]) == [], [
        f.__dict__ for f in gating(res["findings"])]
    for name in ("decode", "verify", "sample", "spec_sample"):
        if name in res["closures"]:
            assert res["closures"][name]["partial_sum_allreduces"] == 0, name


def test_audit_engine_megatron_trips_partial_sum_gate(mesh):
    """Contrast: hold the megatron baseline to the cascade contract
    (max_partial_sum_allreduces=0) and the auditor must report
    collective-budget findings on the decode-path closures — while the
    engine's own default contract (megatron -> uncapped) stays quiet."""
    from repro.analysis import contract
    from repro.analysis.findings import gating
    cfg, model = registry.load(registry.FAMILY_SMOKE["transformer"], smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    eng = ServeEngine(model, params, CCFG, _scfg(tp_policy="megatron"),
                      mesh=mesh)
    strict = contract.ServingContract(max_partial_sum_allreduces=0)
    res = contract.audit_engine(eng, strict)
    bad = [f for f in gating(res["findings"])
           if f.check == "collective-budget"]
    assert any(f.where == "decode" for f in bad), res["findings"]
    assert res["closures"]["decode"]["partial_sum_allreduces"] > 0
    # the default contract reads the engine's policy: megatron is uncapped
    assert gating(contract.audit_engine(eng)["findings"]) == []


def test_sampled_decode_step_has_zero_partial_sum_allreduce(mesh):
    """Sampling must not reintroduce partial-sum traffic: the FUSED sampled
    step (the computation a temperature>0 engine actually dispatches, and
    the one decode_step_hlo lowers when sampling is on) pins the logits row
    replicated before top-k/Gumbel, so its HLO stays AR-free too."""
    from benchmarks import hlo_analysis
    cfg, model = registry.load(registry.FAMILY_SMOKE["transformer"], smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    eng = ServeEngine(model, params, CCFG,
                      _scfg(temperature=0.8, top_k=5), mesh=mesh)
    ar = hlo_analysis.partial_sum_allreduces(eng.decode_step_hlo())
    assert ar["count"] == 0, ar["ops"]

"""Paged KV pool + radix-tree prefix cache: token-exactness, downgrades,
COW, eviction, failover — plus hypothesis property tests on the host-side
allocator/tree.

The paged engine's contract is TOKEN-EXACT parity with the dense PR-8
engine (not allclose): the gathered page views reconstruct exactly what
the dense cache would attend over, garbage rows mask to softmax weight
0.0, and the radix tree only ever re-pins pages whose contents encode the
matched prefix. Every parity test here serves a shared-system-prompt
request mix through both engines and compares streams token-for-token.
"""
import jax

# sampled parity compares engines constructed in one process: the flag must
# flip BEFORE any params are drawn (see the engine's construction warning)
jax.config.update("jax_threefry_partitionable", True)
jax.config.update("jax_platform_name", "cpu")

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.elastic import ReplicaSet
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.prefix import PagePool, RadixPrefixCache
from repro.serve.traffic import VirtualClock

CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)

#: full-attention families take the page-gather read path; ring-buffer /
#: recurrent state has no page-granular layout and must downgrade
PAGED_FAMILIES = ["transformer", "moe"]
DENSE_FAMILIES = ["griffin", "ssm"]


def _load(family):
    cfg, model = registry.load(registry.FAMILY_SMOKE[family], smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


@pytest.fixture(scope="module")
def transformer():
    return _load("transformer")


def _shared_reqs(cfg, n=4, prefix_len=24, suffix_len=6, max_new=8, seed=0):
    """n requests sharing one prefix (fresh objects every call — requests
    are mutated by the engine, so parity runs each need their own)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [shared,
                         rng.integers(0, cfg.vocab, suffix_len).astype(np.int32)]),
                    max_new_tokens=max_new)
            for i in range(n)]


def _run(model, reqs, params, *, prefix_cache, draft_len=0, temperature=0.0,
         batched=True, max_batch=2, num_pages=0, page_size=8):
    scfg = ServeConfig(max_batch=max_batch, max_len=64, batched=batched,
                       prefill_chunk=8, draft_len=draft_len,
                       temperature=temperature, top_k=8,
                       prefix_cache=prefix_cache, page_size=page_size,
                       num_pages=num_pages)
    eng = ServeEngine(model, params, CCFG, scfg)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, [list(r.tokens_out) for r in reqs]


# ---------------------------------------------------------------------------
# token-exact parity vs the dense engine, per supported family x mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", PAGED_FAMILIES)
def test_paged_greedy_token_exact(family):
    """Paged + prefix-cached serving of a shared-prefix mix emits exactly
    the dense engine's greedy tokens, with real prefix hits along the way."""
    cfg, model, params = _load(family)
    _, ref = _run(model, _shared_reqs(cfg), params, prefix_cache=False)
    eng, out = _run(model, _shared_reqs(cfg), params, prefix_cache=True)
    assert eng.paged and eng.effective_mode == "batched-greedy-paged"
    assert not eng.downgrades
    assert out == ref
    assert eng.metrics()["prefix_hits"] > 0


def test_paged_spec_token_exact(transformer):
    """Speculative decode on the paged pool (checkpoint carries the block
    table; rewind restores through it) commits exactly the dense spec
    engine's tokens."""
    cfg, model, params = transformer
    _, ref = _run(model, _shared_reqs(cfg), params, prefix_cache=False,
                  draft_len=3)
    eng, out = _run(model, _shared_reqs(cfg), params, prefix_cache=True,
                    draft_len=3)
    assert eng.effective_mode == "spec-greedy-paged"
    assert out == ref


def test_paged_sampled_token_exact(transformer):
    """Seeded sampling: identical logits through the page gather + the same
    fold_in draw order means identical realizations token-for-token."""
    cfg, model, params = transformer
    _, ref = _run(model, _shared_reqs(cfg), params, prefix_cache=False,
                  temperature=0.7)
    eng, out = _run(model, _shared_reqs(cfg), params, prefix_cache=True,
                    temperature=0.7)
    assert eng.effective_mode == "batched-sampled-paged"
    assert out == ref


def test_paged_spec_sampled_token_exact(transformer):
    """Speculative SAMPLING (rejection resampling) over paged state stays
    realization-exact with the dense engine."""
    cfg, model, params = transformer
    _, ref = _run(model, _shared_reqs(cfg), params, prefix_cache=False,
                  draft_len=3, temperature=0.7)
    eng, out = _run(model, _shared_reqs(cfg), params, prefix_cache=True,
                    draft_len=3, temperature=0.7)
    assert eng.effective_mode == "spec-sampled-paged"
    assert out == ref


def test_paged_without_prefix_cache_token_exact(transformer):
    """paged=True alone (no radix tree) is the pure pool refactor: same
    tokens, zero prefix machinery engaged."""
    cfg, model, params = transformer
    _, ref = _run(model, _shared_reqs(cfg), params, prefix_cache=False)
    scfg = ServeConfig(max_batch=2, max_len=64, batched=True, prefill_chunk=8,
                       paged=True, page_size=8)
    eng = ServeEngine(model, params, CCFG, scfg)
    reqs = _shared_reqs(cfg)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert eng.paged and eng.prefix is None
    assert [list(r.tokens_out) for r in reqs] == ref
    assert eng.metrics()["prefix_lookups"] == 0


# ---------------------------------------------------------------------------
# failover: kill a paged replica mid-decode, survivors re-pin token-exactly
# ---------------------------------------------------------------------------

def _fleet_run(model, params, reqs, *, prefix_cache):
    clk = VirtualClock()
    scfg = ServeConfig(max_batch=2, max_len=64, batched=True, prefill_chunk=8,
                       prefix_cache=prefix_cache, page_size=8)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg, clock=clk)
                     for _ in range(2)],
                    clock=clk, step_cost=lambda i: 0.01)
    for r in reqs:
        rs.submit(r)
    for _ in range(6):
        rs.step()
    rs.kill_replica(0)
    rs.drain(max_steps=2000)
    final = {}
    for e in rs.engines:
        for r in e._retired:
            cur = final.get(r.uid)
            if cur is None or len(r.tokens_out) > len(cur.tokens_out):
                final[r.uid] = r
    return rs, {u: list(r.tokens_out) for u, r in final.items()}


def test_paged_failover_token_exact(transformer):
    """A hard replica loss mid-decode: aborted slots release their pages,
    rebuilt streams re-admit (and re-pin shared pages) on the survivor, and
    every stream's final tokens match the dense fleet run exactly."""
    cfg, model, params = transformer
    _, ref = _fleet_run(model, params,
                        _shared_reqs(cfg, n=4, max_new=16),
                        prefix_cache=False)
    rs, out = _fleet_run(model, params,
                         _shared_reqs(cfg, n=4, max_new=16),
                         prefix_cache=True)
    assert out == ref
    # the killed engine's slots all released their pages: whatever is still
    # resident is tree-held only (refcount exactly 1, trash page aside)
    dead = rs.engines[0]
    assert dead.paged
    held = dead.pool.refcount[1:]
    assert (held[held > 0] == 1).all(), held


def test_paged_abort_releases_every_page(transformer):
    """abort_in_flight on a prefix-cached engine leaves no slot-held pages
    behind — only tree refs survive, and evicting the whole tree drains the
    pool to empty (no leaked refcounts)."""
    cfg, model, params = transformer
    scfg = ServeConfig(max_batch=2, max_len=64, batched=True, prefill_chunk=8,
                       prefix_cache=True, page_size=8)
    eng = ServeEngine(model, params, CCFG, scfg)
    for r in _shared_reqs(cfg, n=3, max_new=32):
        eng.submit(r)
    for _ in range(6):
        eng.step()
    assert eng.busy()
    eng.abort_in_flight()
    held = eng.pool.refcount[1:]
    assert (held[held > 0] == 1).all(), held          # tree-only residents
    eng.prefix.evict(eng.pool.num_pages)              # drop the whole tree
    assert eng.pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# COW + eviction behavior
# ---------------------------------------------------------------------------

def test_paged_cow_divergent_suffix_token_exact(transformer):
    """Two prompts diverging MID-page: the second admission clones the
    partially shared page (COW) instead of re-prefilling it — and instead
    of corrupting the first stream's published page. Serial admission
    (max_batch=1) forces the second request to see the first's tree entry."""
    cfg, model, params = transformer

    def reqs():
        rng = np.random.default_rng(3)
        shared = rng.integers(0, cfg.vocab, 12).astype(np.int32)  # 1.5 pages
        tails = [rng.integers(0, cfg.vocab, 6).astype(np.int32)
                 for _ in range(2)]
        return [Request(uid=i, prompt=np.concatenate([shared, tails[i]]),
                        max_new_tokens=8) for i in range(2)]

    _, ref = _run(model, reqs(), params, prefix_cache=False, max_batch=1)
    eng, out = _run(model, reqs(), params, prefix_cache=True, max_batch=1)
    assert out == ref
    # page_size 8, shared 12 tokens: one full-page hit (8) + a 4-token COW
    # tail => more hit tokens than the full pages alone account for
    assert eng.metrics()["prefix_hits"] > 8


def test_paged_eviction_under_pool_pressure(transformer):
    """Distinct prompts through a deliberately tight pool: the watermark
    evicts LRU tree-only pages to keep admission allocable, and the streams
    stay token-exact with the dense engine throughout."""
    cfg, model, params = transformer

    def reqs():
        rng = np.random.default_rng(7)
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, 30).astype(np.int32),
                        max_new_tokens=6) for i in range(4)]

    _, ref = _run(model, reqs(), params, prefix_cache=False, max_batch=1)
    # max_len 64 / page_size 8 -> 8 blocks per slot; 9 pages is the minimum
    # pool (1 slot + trash), so every retained tree page fights the watermark
    eng, out = _run(model, reqs(), params, prefix_cache=True, max_batch=1,
                    num_pages=9)
    assert out == ref
    m = eng.metrics()
    assert m["evictions"] > 0
    assert m["pages_in_use"] <= m["pages_total"]


def test_paged_hit_rate_meets_bar(transformer):
    """The acceptance-criteria bar, unit-pinned: a shared-system-prompt mix
    (long shared prefix, short unique tail) serves with prefix_hit_rate
    >= 0.5 — most prompt tokens come from resident pages, not prefill."""
    cfg, model, params = transformer
    eng, _ = _run(model,
                  _shared_reqs(cfg, n=6, prefix_len=24, suffix_len=6),
                  params, prefix_cache=True, max_batch=1)
    m = eng.metrics()
    assert m["prefix_lookups"] == 6 * 30
    assert m["prefix_hit_rate"] >= 0.5, m["prefix_hit_rate"]
    assert m["pages_in_use"] > 0


# ---------------------------------------------------------------------------
# downgrades: never silently run a different path than reported
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", DENSE_FAMILIES)
def test_paged_downgrades_on_non_attention_state(family):
    """Ring-buffer / recurrent caches have no page-granular layout: the
    engine must record the downgrade, warn once, report an un-suffixed
    mode — and still serve the exact dense tokens."""
    cfg, model, params = _load(family)
    _, ref = _run(model, _shared_reqs(cfg, n=2, max_new=4), params,
                  prefix_cache=False)
    with pytest.warns(RuntimeWarning, match="paged KV requested"):
        eng, out = _run(model, _shared_reqs(cfg, n=2, max_new=4), params,
                        prefix_cache=True)
    assert not eng.paged and eng.prefix is None
    assert not eng.effective_mode.endswith("-paged")
    assert any("paged" in d for d in eng.downgrades)
    assert out == ref


def test_paged_downgrades_on_slotwise_path(transformer):
    cfg, model, params = transformer
    with pytest.warns(RuntimeWarning, match="paged KV requested"):
        eng, _ = _run(model, _shared_reqs(cfg, n=1, max_new=2), params,
                      prefix_cache=True, batched=False)
    assert not eng.paged
    assert not eng.effective_mode.endswith("-paged")


def test_paged_metrics_keys(transformer):
    cfg, model, params = transformer
    eng, _ = _run(model, _shared_reqs(cfg, n=2, max_new=2), params,
                  prefix_cache=True)
    m = eng.metrics()
    assert m["paged"] is True and m["prefix_cache"] is True
    for k in ("prefix_hit_rate", "pages_in_use", "pages_total", "evictions",
              "page_size", "prefix_hits", "prefix_lookups"):
        assert k in m, k
    assert m["page_size"] == 8
    assert m["effective_mode"].endswith("-paged")


# ---------------------------------------------------------------------------
# hypothesis property tests: the host-side allocator + radix tree
# ---------------------------------------------------------------------------

_seeds = st.integers(0, 2**31 - 1)


@settings(max_examples=50, deadline=None)
@given(_seeds, st.integers(2, 24))
def test_pool_alloc_release_invariants(seed, num_pages):
    """Random alloc/ref/release interleavings preserve the pool's books:
    no double-assignment, page 0 pinned, counts never negative, and
    free + in_use always partitions the allocatable pages."""
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages)
    live = {}                                    # page -> our refcount
    for _ in range(200):
        op = rng.integers(0, 3)
        if op == 0 and pool.free_pages:
            pg = pool.alloc()
            assert pg not in live and 0 < pg < num_pages
            live[pg] = 1
        elif op == 1 and live:
            pg = int(rng.choice(list(live)))
            pool.ref(pg)
            live[pg] += 1
        elif op == 2 and live:
            pg = int(rng.choice(list(live)))
            pool.release(pg)
            live[pg] -= 1
            if live[pg] == 0:
                del live[pg]
        assert pool.refcount[0] == 1             # trash page pinned
        assert (pool.refcount >= 0).all()
        assert pool.free_pages + pool.pages_in_use == num_pages - 1
        assert pool.pages_in_use == len(live)
        for pg, n in live.items():
            assert pool.refcount[pg] == n


@settings(max_examples=30, deadline=None)
@given(_seeds, st.integers(2, 4), st.integers(1, 5))
def test_radix_insert_match_evict_roundtrip(seed, page_size, n_prompts):
    """insert -> match returns the SAME physical pages for every full page
    of the prompt (capped at len-1); releasing all slot/match refs and
    evicting everything drains the pool to zero — no leaked refcounts."""
    rng = np.random.default_rng(seed)
    pool = PagePool(64)
    tree = RadixPrefixCache(pool, page_size)
    published = {}
    for _ in range(n_prompts):
        prompt = [int(t) for t in rng.integers(0, 3, rng.integers(1, 13))]
        n_full = len(prompt) // page_size
        slot_pages = [pool.alloc() for _ in range(n_full)]
        tree.insert(prompt, slot_pages)
        for pg in slot_pages:                    # slot retires
            pool.release(pg)
        published[tuple(prompt)] = True
        m = tree.match(prompt)
        # a full page only matches if it fits under the len-1 cap
        want_full = min(n_full * page_size, len(prompt) - 1) // page_size
        assert len(m.pages) == want_full
        assert m.matched == want_full * page_size
        for pg in m.pages:
            assert pool.refcount[pg] == 2        # tree + our match ref
            pool.release(pg)
    tree.evict(pool.num_pages)
    assert pool.pages_in_use == 0
    for prompt in published:
        m = tree.match(list(prompt))
        assert m.pages == [] and m.matched == 0


@settings(max_examples=30, deadline=None)
@given(_seeds, st.integers(2, 4))
def test_radix_cow_never_mutates_shared_page(seed, page_size):
    """A divergent-tail match clones the shared page through the COW hook:
    the original page's (host-simulated) contents are untouched, the clone
    is a distinct page, and the tree still holds the original."""
    rng = np.random.default_rng(seed)
    pool = PagePool(32)
    store = {}                                   # page -> contents snapshot

    def copy_page(src):
        dst = pool.alloc() if pool.free_pages else None
        if dst is not None:
            store[dst] = list(store[src])
        return dst

    tree = RadixPrefixCache(pool, page_size, copy_page=copy_page)
    prompt = [int(t) for t in rng.integers(0, 3, 2 * page_size)]
    pages = [pool.alloc() for _ in range(2)]
    for pg, i in zip(pages, range(2)):
        store[pg] = prompt[i * page_size:(i + 1) * page_size]
    tree.insert(prompt, pages)
    for pg in pages:
        pool.release(pg)
    # diverge INSIDE the second page (first token of it), keep one extra
    # token so the len-1 cap still allows the full first page
    div = list(prompt)
    div[page_size] = (div[page_size] + 1) % 3
    div.append(0)
    before = {pg: list(c) for pg, c in store.items()}
    m = tree.match(div)
    assert m.pages[0] == pages[0]                # full-page hit, same page
    if len(m.pages) > 1:                         # COW tail engaged
        assert m.cow
        clone = m.pages[-1]
        assert clone not in pages
        assert store[clone] == before[pages[1]]  # copied at clone time
    for pg in pages:                             # originals unmodified
        assert store[pg] == before[pg]
    assert tree.match(prompt + [0]).pages[:2] == pages  # tree intact: both
                                                        # originals still hit


@settings(max_examples=30, deadline=None)
@given(_seeds)
def test_radix_eviction_is_lru_and_bounded(seed):
    """evict(n) frees at most n pages, only tree-only (refcount-1) pages,
    in least-recently-used order; slot-pinned pages are never victims."""
    rng = np.random.default_rng(seed)
    pool = PagePool(64)
    tree = RadixPrefixCache(pool, page_size=2)
    prompts = [[i, i, i, i] for i in range(5)]
    pages = {}
    for p in prompts:
        pgs = [pool.alloc(), pool.alloc()]
        tree.insert(p, pgs)
        pages[tuple(p)] = pgs
        for pg in pgs:
            pool.release(pg)
    # touch prompts in a random order; then pin one prompt's pages as if a
    # slot still mapped them
    order = list(rng.permutation(len(prompts)))
    for i in order:
        m = tree.match(prompts[i] + [9])
        for pg in m.pages:
            pool.release(pg)
    pinned = prompts[order[0]]                   # least recently used
    mp = tree.match(pinned + [9])                # pin via match refs
    assert mp.pages
    before = pool.pages_in_use
    freed = tree.evict(3)
    assert freed <= 3
    assert pool.pages_in_use == before - freed
    # the pinned (refcount-2) pages survived even though they are LRU
    for pg in pages[tuple(pinned)]:
        assert pool.refcount[pg] >= 1
    m2 = tree.match(pinned + [9])
    assert m2.pages == mp.pages
    for pg in mp.pages + m2.pages:
        pool.release(pg)

"""FP4/FP5/FP8 numerics — unit + property tests for repro.core.quant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, strategies as st

from repro.core import quant

jax.config.update("jax_platform_name", "cpu")


def test_fp4_codec_roundtrip_all_codes():
    codes = jnp.arange(16, dtype=jnp.uint8)
    vals = quant.fp4_decode(codes)
    np.testing.assert_array_equal(
        np.abs(np.asarray(vals)), np.concatenate([quant.FP4_VALUES, quant.FP4_VALUES]))
    assert bool(jnp.all(quant.fp4_encode(vals) == codes))


def test_fp4_encode_matches_native_cast():
    ml_dtypes = pytest.importorskip("ml_dtypes")
    x = np.linspace(-8, 8, 1001, dtype=np.float32)
    ours = quant.fp4_decode(quant.fp4_encode(jnp.asarray(x)))
    native = x.astype(ml_dtypes.float4_e2m1fn).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(ours), native)


def test_pack_unpack_identity():
    c = jnp.arange(64, dtype=jnp.uint8).reshape(16, 4) % 16
    for axis in (0,):
        assert bool(jnp.all(quant.unpack_fp4(quant.pack_fp4(c, axis), axis) == c))


def test_fp5_product_exhaustive():
    """All 256 FP4xFP4 products: exact except both-mantissa-1.1 cases, which
    truncate 10.01b -> 10b (paper Section 10.6)."""
    vals = quant.fp4_decode(jnp.arange(16, dtype=jnp.uint8))
    a = jnp.repeat(vals, 16)
    b = jnp.tile(vals, 16)
    p = np.asarray(quant.fp5_e3m1_product(a, b))
    exact = np.asarray(a * b)
    # mantissa of |exact| has >1 bit only for 1.5*1.5-type products
    both_wide = (np.abs(np.asarray(a)) % np.exp2(np.floor(np.log2(np.maximum(np.abs(a), 1e-9)))) != 0) & \
                (np.abs(np.asarray(b)) % np.exp2(np.floor(np.log2(np.maximum(np.abs(b), 1e-9)))) != 0)
    # where not both-wide, product must be exact
    np.testing.assert_array_equal(p[~both_wide & (exact != 0)], exact[~both_wide & (exact != 0)])
    # truncation is always toward zero and within one ulp
    assert np.all(np.abs(p) <= np.abs(exact))
    nz = exact != 0
    assert np.all(np.abs(p[nz] - exact[nz]) <= np.abs(exact[nz]) * 0.25 + 1e-9)


def test_fp5_range_covers_all_products_without_saturation():
    vals = quant.FP4_VALUES[1:]  # nonzero magnitudes
    prods = np.outer(vals, vals)
    assert prods.max() == 36.0 and prods.min() == 0.25
    # E3M1 bias-2: normal range [2^-2, 1.5*2^5]; 36 truncates to 32 (exp 5)
    assert float(quant.fp5_e3m1_product(jnp.float32(6.0), jnp.float32(6.0))) == 32.0


def test_fp8_truncate_properties():
    x = jnp.array([500.0, -500.0, 448.0, 1.0625, 2.0 ** -10, -0.9999, 0.0])
    y = np.asarray(quant.fp8_e4m3_truncate(x))
    assert y[0] == 448.0 and y[1] == -448.0       # saturation
    assert y[2] == 448.0
    assert y[3] == 1.0                             # truncation toward zero
    assert y[4] == 0.0                             # below subnormal step
    assert abs(y[5]) <= 0.9999                     # magnitude never grows
    assert y[6] == 0.0


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=-1000, max_value=1000, allow_nan=False))
def test_fp8_truncate_idempotent_and_monotone_magnitude(v):
    x = jnp.float32(v)
    y = quant.fp8_e4m3_truncate(x)
    y2 = quant.fp8_e4m3_truncate(y)
    assert float(y) == float(y2)                   # idempotent
    assert abs(float(y)) <= min(abs(v), 448.0) + 1e-6  # truncation toward zero


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.sampled_from([16, 64, 128]), st.sampled_from([8, 16, 32]),
       st.sampled_from([0, 16]))
def test_ptq_roundtrip_error_bound(seed, k, n, group):
    """Group-absmax FP4 PTQ: |w - deq(q(w))| <= group_absmax / 6.
    The widest FP4 gap is 4 -> 6 (= 2 raw, scaled by absmax/6); RNE error is
    at most half that gap = absmax/6."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k, n)) * 0.05
    packed, scales = quant.quantize_weight(w, group_size=group)
    wd = quant.dequantize_weight(packed, scales, jnp.float32)
    g = group if group else k
    wg = np.asarray(w).reshape(k // g, g, n)
    absmax = np.abs(wg).max(axis=1, keepdims=True)
    err = np.abs(np.asarray(wd).reshape(k // g, g, n) - wg)
    assert np.all(err <= absmax / 6.0 + 1e-7)


def test_cascade_exact_oracle_representable_case():
    """When every partial sum is exactly representable in FP8 E4M3, the
    CASCADE column accumulation must be bit-exact vs f32: 16 adds of 0.5
    (steps stay within the 3-bit mantissa at every exponent <= 3)."""
    x4 = jnp.ones((2, 16))
    w4 = jnp.full((16, 3), 0.5)
    exact = np.asarray(quant.cascade_matmul_exact(x4, w4))
    np.testing.assert_array_equal(exact, np.full((2, 3), 8.0))


def test_cascade_exact_oracle_well_scaled_statistics():
    """For well-scaled inputs (the regime the paper's FP8 accumulators are
    designed for — Section 10.4 picks FP8-over-INT8 for dynamic range), the
    truncating accumulation tracks f32 within a bounded relative Frobenius
    error, and saturates at +/-448."""
    key = jax.random.PRNGKey(0)
    x4 = quant.fp4_decode(quant.fp4_encode(jax.random.normal(key, (8, 64)) * 0.4))
    w4 = quant.fp4_decode(quant.fp4_encode(jax.random.normal(jax.random.PRNGKey(1), (64, 16)) * 0.4))
    exact = np.asarray(quant.cascade_matmul_exact(x4, w4))
    ref = np.asarray(x4 @ w4)
    rel_fro = np.linalg.norm(exact - ref) / (np.linalg.norm(ref) + 1e-9)
    assert rel_fro < 0.25, f"FP8 accumulation drift too large: {rel_fro}"
    assert np.all(np.abs(exact) <= 448.0)          # saturation respected


def test_cascade_exact_bias_preload():
    """Biases preloaded into the output-sum HILT (paper Section 13.1)."""
    x4 = jnp.ones((2, 4))
    w4 = jnp.ones((4, 3))
    bias = jnp.array([1.0, -1.0, 0.5])
    out = quant.cascade_matmul_exact(x4, w4, bias=jnp.broadcast_to(bias, (2, 3)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(4.0 + bias)[None].repeat(2, 0))


def test_fake_quant_fp4_ste_gradient():
    w = jnp.array([[0.3, -0.7], [1.2, 0.01]])
    g = jax.grad(lambda w: jnp.sum(quant.fake_quant_fp4(w) ** 2))(w)
    # STE: gradient flows as if identity: d/dw sum(fq(w)^2) ~= 2*fq(w)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(quant.fake_quant_fp4(w)), rtol=1e-5)


def test_fake_quant_fp4_forward_is_quantized():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 16))
    fq = quant.fake_quant_fp4(w)
    packed, scales = quant.quantize_weight(w)
    wd = quant.dequantize_weight(packed, scales, jnp.float32)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(wd), atol=1e-6)


def test_fp4_all_codes_full_chain_roundtrip():
    """All 16 codes survive the FULL serving chain bit-exactly:
    decode -> fp4_round fixed point -> encode -> pack -> unpack."""
    codes = jnp.arange(16, dtype=jnp.uint8).reshape(8, 2)
    vals = quant.fp4_decode(codes)
    # every representable value is a fixed point of the RNE rounder
    np.testing.assert_array_equal(np.asarray(quant.fp4_round(vals)),
                                  np.asarray(vals))
    re = quant.fp4_encode(vals)
    assert bool(jnp.all(re == codes))
    packed = quant.pack_fp4(re, 0)
    assert packed.dtype == jnp.uint8 and packed.shape == (4, 2)
    assert bool(jnp.all(quant.unpack_fp4(packed, 0) == codes))


def test_quantize_weight_odd_k_pads_zero_row():
    """Odd-K weights pack via one all-zero pad row; dequantize returns the
    padded (K+1)-row matrix whose extra row is exactly zero."""
    w = jax.random.normal(jax.random.PRNGKey(7), (33, 6)) * 0.3
    packed, scales = quant.quantize_weight(w)
    assert packed.shape == (17, 6)
    deq = quant.dequantize_weight(packed, scales, jnp.float32)
    assert deq.shape == (34, 6)
    np.testing.assert_array_equal(np.asarray(deq[-1]), np.zeros(6, np.float32))
    with pytest.raises(AssertionError):
        quant.quantize_weight(w, group_size=3)   # grouped scales need even K

"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, strategies as st

from repro.core import crest, quant, sparsity

jax.config.update("jax_platform_name", "cpu")

_seeds = st.integers(0, 2**31 - 1)


# ---------------------------------------------------------------------------
# quantization invariants
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(_seeds)
def test_quantization_idempotent(seed):
    """q(deq(q(w))) == q(w): the FP4 grid is a fixed point."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 8)) * 0.3
    p1, s1 = quant.quantize_weight(w)
    w1 = quant.dequantize_weight(p1, s1, jnp.float32)
    p2, s2 = quant.quantize_weight(w1)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(_seeds, st.floats(0.125, 8.0))
def test_quantization_scale_equivariance(seed, scale):
    """Scaling a weight matrix scales its dequantized form (absmax scales
    pass through): deq(q(s*w)) == s * deq(q(w))."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (16, 4))
    a = quant.dequantize_weight(*quant.quantize_weight(w * scale), jnp.float32)
    b = quant.dequantize_weight(*quant.quantize_weight(w), jnp.float32) * scale
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=2, max_size=64).filter(lambda l: len(l) % 2 == 0))
def test_pack_unpack_roundtrip_any_codes(codes):
    c = jnp.asarray(codes, jnp.uint8)[:, None]
    assert bool(jnp.all(quant.unpack_fp4(quant.pack_fp4(c, 0), 0) == c))


@settings(max_examples=100, deadline=None)
@given(st.floats(0.0, 400.0), st.floats(0.0, 400.0))
def test_fp8_truncation_monotone(a, b):
    """Round-toward-zero is monotone on non-negatives."""
    lo, hi = sorted([a, b])
    ta = float(quant.fp8_e4m3_truncate(jnp.float32(lo)))
    tb = float(quant.fp8_e4m3_truncate(jnp.float32(hi)))
    assert ta <= tb


@settings(max_examples=50, deadline=None)
@given(_seeds)
def test_fake_quant_zero_gradient_residual(seed):
    """STE: grad(mean(fq(w))) == grad(mean(w)) exactly."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (8, 8))
    g1 = jax.grad(lambda w: jnp.mean(quant.fake_quant_fp4(w)))(w)
    g2 = jax.grad(lambda w: jnp.mean(w))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-7)


# ---------------------------------------------------------------------------
# sparsity invariants (paper Section 10.13)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(_seeds, st.sampled_from([0.1, 0.25, 0.5, 0.9]))
def test_topk_sparsity_density_and_idempotence(seed, density):
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 8))
    ws, mask = sparsity.topk_sparsify(w, density)
    kept = float(jnp.mean(jnp.sum(mask, axis=0) / 64))
    assert abs(kept - density) < 0.05
    ws2, _ = sparsity.topk_sparsify(ws, density)
    np.testing.assert_array_equal(np.asarray(ws), np.asarray(ws2))
    # kept entries are untouched; dropped entries are exactly zero
    np.testing.assert_array_equal(np.asarray(ws)[np.asarray(mask)],
                                  np.asarray(w)[np.asarray(mask)])
    assert np.all(np.asarray(ws)[~np.asarray(mask)] == 0)


def test_sparsity_activity_factor_matches_paper():
    """Table 5: alpha = 0.10*(1-s) + 0.04*s = 0.046 at s=0.90."""
    w = jnp.ones((100, 10)).at[: 90].set(0.0)
    stats = sparsity.sparsity_stats(w)
    assert abs(stats["sparsity"] - 0.9) < 1e-6
    assert abs(stats["activity_factor"] - 0.046) < 1e-6


# ---------------------------------------------------------------------------
# CREST invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(_seeds, st.integers(0, 4))
def test_crest_eventually_detects_any_fault_set(seed, n_faults):
    """For any fault set with <= n_spares faults, enough probe cycles detect
    and repair every fault with zero false positives."""
    cfg = crest.CrestConfig(n_spares=4, threshold=2)
    n, k = 24, 8
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n))
    fault = (crest.inject_column_faults(jax.random.PRNGKey(seed + 1), n, n_faults)
             if n_faults else jnp.zeros((n,), bool))
    state = crest.crest_init(n, cfg)
    step = jax.jit(lambda x, s: crest.crest_matmul(x, w, s, cfg, fault if n_faults else None))
    cycles = (n // cfg.n_spares) * (cfg.threshold + 1) + 2
    for i in range(cycles):
        x = jax.random.normal(jax.random.PRNGKey(1000 + seed + i), (4, k))
        y, state = step(x, state)
    stats = crest.coverage_stats(state, fault)
    assert stats["detected"] == n_faults
    assert stats["false_positives"] == 0
    assert stats["repaired"] == n_faults


# ---------------------------------------------------------------------------
# CASCADE schedule invariants (paper Table 6 model)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 18), st.integers(1, 8), st.integers(1, 6))
def test_cascade_schedule_efficiency_monotone_in_batches(log2_batches, rows_k, arrays_k):
    import sys, os
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.paper_tables import cascade_schedule
    rows_per = 64
    rows_total = rows_per * 64 * arrays_k
    s1 = cascade_schedule(batches=2 ** log2_batches, rows_total=rows_total,
                          cols=1024 * rows_k, rows_per_array=rows_per)
    s2 = cascade_schedule(batches=2 ** log2_batches * 2, rows_total=rows_total,
                          cols=1024 * rows_k, rows_per_array=rows_per)
    # pipeline fill amortizes: efficiency strictly increases with batches
    assert s2["efficiency"] > s1["efficiency"]
    assert s2["efficiency"] < 1.0
    # cycles are affine in batches with unit slope
    assert s2["total_cycles"] - s1["total_cycles"] == 2 ** log2_batches


# ---------------------------------------------------------------------------
# data pipeline invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(_seeds, st.integers(0, 10_000))
def test_data_pipeline_pure_function_of_step(seed, step):
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=seed % 1000)
    a = SyntheticCorpus(cfg).batch_at(step)
    b = SyntheticCorpus(cfg).batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 64


# ---------------------------------------------------------------------------
# sharding-policy invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["cascade", "megatron"]),
       st.sampled_from(["qwen2.5-32b", "olmoe-1b-7b", "recurrentgemma-2b"]))
def test_param_specs_rank_safe(policy, arch):
    """Every generated PartitionSpec has rank <= leaf rank, and mentions only
    mesh axes (no stale names)."""
    from repro.core.cascade import CascadeConfig
    from repro.distributed import sharding as shd
    from repro.models import registry
    cfg, model = registry.load(arch, smoke=True)
    pshape = jax.eval_shape(lambda: model.init_params(
        jax.random.PRNGKey(0), CascadeConfig(mode="train")))
    specs = shd.param_specs(pshape, policy)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or
                             type(x).__name__ == "PartitionSpec")
    flat_l = jax.tree.leaves(pshape)
    assert len(flat_s) == len(flat_l)
    for sp, lf in zip(flat_s, flat_l):
        assert len(sp) <= lf.ndim, (sp, lf.shape)
        for part in sp:
            if part is None:
                continue
            parts = part if isinstance(part, tuple) else (part,)
            assert set(parts) <= {"pod", "data", "model"}


@settings(max_examples=30, deadline=None)
@given(_seeds, st.sampled_from([0.05, 0.1, 0.25]))
def test_sparsity_survives_fp4_quantization(seed, density):
    """Paper Sections 4 + 10.13: Top-K sparsity composes with FP4 PTQ —
    zero is exactly representable in E2M1, so every pruned weight stays
    exactly zero through quantize->dequantize (the power-saving zeros are
    preserved in the serving format)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 8))
    ws, mask = sparsity.topk_sparsify(w, density)
    wq = quant.dequantize_weight(*quant.quantize_weight(ws), jnp.float32)
    assert np.all(np.asarray(wq)[~np.asarray(mask)] == 0.0)
    stats = sparsity.sparsity_stats(wq)
    assert stats["sparsity"] >= 1.0 - density - 1e-6


@settings(max_examples=25, deadline=None)
@given(_seeds, st.integers(1, 9), st.integers(3, 130), st.integers(1, 40))
def test_packed_matmul_matches_ref_random_shapes(seed, m, k, n):
    """ops.cascade_matmul on arbitrary (M, K, N) — odd K included, which
    exercises quantize_weight's zero-row pad-to-pack and the matching
    activation pad — agrees with the ref.py dequant-matmul oracle."""
    from repro.kernels import ops
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(keys[0], (k, n)) * 0.2
    packed, scales = quant.quantize_weight(w)
    x = jax.random.normal(keys[1], (m, k))
    out = ops.cascade_matmul(x, packed, scales, interpret=True)
    ref = ops.cascade_matmul_ref(x, packed, scales)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=16, max_size=16))
def test_fp4_code_lists_roundtrip_through_values(codes):
    """Arbitrary code vectors survive decode -> encode bit-exactly (the
    16-point E2M1 grid is a codec fixed point, signs included)."""
    c = jnp.asarray(codes, jnp.uint8)[:, None]
    assert bool(jnp.all(quant.fp4_encode(quant.fp4_decode(c)) == c))

"""Batched continuous-batching engine: parity, invariants, fault injection.

The batched path must be a pure optimization: token-exact against the
slot-wise reference on every schedule (whole-prompt, chunked prefill,
token-budget interleaving), with admission/retirement behaving as a FIFO
slot grid and CREST probes still confirming injected faults.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.engine import Request, ServeConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")

CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def tiny_model():
    cfg, model = registry.load("codeqwen1.5-7b", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, int(n)).astype(np.int32),
                    max_new_tokens=max_new) for i, n in enumerate(lens)]


def _run(model, params, cfg, lens, scfg, max_new=4, seed=0, max_steps=400):
    eng = ServeEngine(model, params, CCFG, scfg)
    reqs = _requests(cfg, lens, max_new=max_new, seed=seed)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps)
    return reqs, eng


# ---------------------------------------------------------------------------
# parity
# ---------------------------------------------------------------------------

def test_batched_equals_slotwise_token_exact(tiny_model):
    """The tentpole guarantee: one jitted batched decode over the slot grid
    produces exactly the tokens of the per-slot reference loop."""
    cfg, model, params = tiny_model
    lens = [8, 5, 12, 8, 3, 20]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=False))
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=64, batched=True,
                                prefill_chunk=8))
    assert eng.batched
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_chunked_prefill_and_budget_token_exact(tiny_model):
    """Chunked prefill (prompt split across engine steps under a token
    budget) must not change any emitted token."""
    cfg, model, params = tiny_model
    lens = [17, 8, 29, 4]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=False))
    out, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8, token_budget=8))
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_batched_decode_is_single_dispatch(tiny_model):
    """All active slots decode in ONE decode_step call per engine step."""
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=4, max_len=64, batched=True))
    for r in _requests(cfg, [8, 8, 8, 8]):
        eng.submit(r)
    calls = []
    inner = eng._decode_fn
    eng._decode_fn = lambda *a: calls.append(1) or inner(*a)
    eng.step()
    assert sum(s is not None for s in eng.slots) == 4
    assert len(calls) == 1, "batched step must issue one decode dispatch"


# ---------------------------------------------------------------------------
# continuous-batching invariants
# ---------------------------------------------------------------------------

def test_fifo_admission_and_slot_reuse(tiny_model):
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True))
    reqs = _requests(cfg, [8] * 5, max_new=3)
    for r in reqs:
        eng.submit(r)
    admitted = []
    for _ in range(100):
        eng.step()
        for s in eng.slots:
            if s is not None and s.uid not in admitted:
                admitted.append(s.uid)
        if not eng.busy():
            break
    assert admitted == [0, 1, 2, 3, 4], "admission must be FIFO"
    assert all(r.done for r in reqs)
    assert all(s is None for s in eng.slots), "retirement must free slots"
    assert not eng.queue


def test_max_new_tokens_retirement_and_queue_drain(tiny_model):
    cfg, model, params = tiny_model
    reqs, eng = _run(model, params, cfg, [8] * 6,
                     ServeConfig(max_batch=3, max_len=64, batched=True),
                     max_new=5)
    assert all(r.done for r in reqs)
    assert all(len(r.tokens_out) == 5 for r in reqs)
    assert not eng.busy()
    m = eng.metrics()
    assert m["requests_finished"] == 6
    assert m["decode_tokens"] == 6 * 4          # first token comes from prefill
    assert m["tokens_per_s"] > 0


def test_eos_retirement_frees_slot_early(tiny_model):
    """Set eos_id to the first decode token of request 0 (learned from a dry
    run): the request must retire early and its slot be reused."""
    cfg, model, params = tiny_model
    probe, _ = _run(model, params, cfg, [8],
                    ServeConfig(max_batch=1, max_len=64, batched=True),
                    max_new=8)
    eos = probe[0].tokens_out[1]                # first *decoded* token
    reqs, eng = _run(model, params, cfg, [8],
                     ServeConfig(max_batch=1, max_len=64, batched=True, eos_id=eos),
                     max_new=8)
    assert reqs[0].done
    assert len(reqs[0].tokens_out) == 2, reqs[0].tokens_out
    assert reqs[0].tokens_out[-1] == eos


def test_budgeted_prefill_interleaves_with_decode(tiny_model):
    """While a long prompt is being chunk-prefilled, an already-resident
    stream must keep producing tokens (bounded decode latency)."""
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True,
                                  prefill_chunk=4, token_budget=4))
    short, long_ = _requests(cfg, [4, 40], max_new=16)
    eng.submit(short)
    eng.step()                                  # short becomes resident
    eng.submit(long_)
    progressed = False
    for _ in range(4):                          # 40-token prompt needs 10 chunks
        before = len(short.tokens_out)
        eng.step()
        if eng._staging is not None and len(short.tokens_out) > before:
            progressed = True
    assert progressed, "decode must advance while a prompt is mid-prefill"
    eng.run_until_drained(200)
    assert short.done and long_.done


def test_evict_and_abort_in_flight(tiny_model):
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True))
    reqs = _requests(cfg, [8, 8, 8], max_new=8)
    for r in reqs:
        eng.submit(r)
    eng.step()
    aborted = eng.abort_in_flight()
    assert {r.uid for r in aborted} == {0, 1}
    assert all(s is None for s in eng.slots)
    assert eng.busy()                           # uid 2 still queued
    eng.run_until_drained(100)
    assert reqs[2].done


def test_failover_clone_continues_token_exact(tiny_model):
    """A request re-queued after replica death must finish with exactly the
    tokens an unkilled run would have produced (greedy decode + idempotent
    regenerate from prompt + emitted prefix)."""
    from repro.serve.elastic import ReplicaSet
    cfg, model, params = tiny_model
    ref, _ = _run(model, params, cfg, [8], ServeConfig(max_batch=1, max_len=64),
                  max_new=8, seed=3)
    scfg = ServeConfig(max_batch=1, max_len=64)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(2)])
    victim = _requests(cfg, [8], max_new=8, seed=3)[0]
    rs.submit(victim)
    for _ in range(3):                         # prefill + a couple of decodes
        rs.step()
    killed_on = next(i for i, e in enumerate(rs.engines) if victim in e.slots)
    rs.kill_replica(killed_on)
    rs.drain(max_steps=200)
    clone = rs.requeued[0]
    assert clone.done
    assert clone.tokens_out == ref[0].tokens_out, (clone.tokens_out, ref[0].tokens_out)


def test_double_failover_still_token_exact(tiny_model):
    """Two successive replica deaths: the rebuild must never double-bake
    emitted tokens into the prompt (prompt_carried bookkeeping)."""
    from repro.serve.elastic import ReplicaSet
    cfg, model, params = tiny_model
    ref, _ = _run(model, params, cfg, [8], ServeConfig(max_batch=1, max_len=64),
                  max_new=10, seed=5)
    scfg = ServeConfig(max_batch=1, max_len=64)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(3)])
    victim = _requests(cfg, [8], max_new=10, seed=5)[0]
    rs.submit(victim)
    live = victim
    for _ in range(2):                         # kill whichever replica holds it, twice
        for _ in range(3):
            rs.step()
        holder = next(i for i, e in enumerate(rs.engines)
                      if live in e.slots and rs.health[i].alive)
        rs.kill_replica(holder)
        live = rs.requeued[-1]
    rs.drain(max_steps=300)
    assert live.done
    assert live.tokens_out == ref[0].tokens_out, (live.tokens_out, ref[0].tokens_out)


def test_unservable_prompts_rejected_not_crashed(tiny_model):
    """Oversized and empty prompts are rejected at admission (never crash or
    clobber the cache); the queue behind them still drains."""
    cfg, model, params = tiny_model
    for batched in (True, False):
        reqs, eng = _run(model, params, cfg, [30, 0, 6],
                         ServeConfig(max_batch=2, max_len=16, batched=batched,
                                     prefill_chunk=8), max_new=3)
        assert reqs[0].done and reqs[0].tokens_out == []   # too long
        assert reqs[1].done and reqs[1].tokens_out == []   # empty
        assert reqs[2].done and len(reqs[2].tokens_out) == 3
        assert not eng.busy()


# ---------------------------------------------------------------------------
# CREST through the batched path
# ---------------------------------------------------------------------------

def test_crest_confirms_faults_through_batched_engine(tiny_model):
    from repro.core import crest as crest_mod
    cfg, model, params = tiny_model
    scfg = ServeConfig(max_batch=2, max_len=48, batched=True,
                       crest_enabled=True, crest_every=1,
                       crest_cfg=crest_mod.CrestConfig(n_spares=8, threshold=2))
    eng = ServeEngine(model, params, CCFG, scfg)
    assert eng.batched
    eng.fault_mask = crest_mod.inject_column_faults(jax.random.PRNGKey(7), cfg.vocab, 3)
    for r in _requests(cfg, [8] * 4, max_new=16):
        eng.submit(r)
    eng.run_until_drained(200)
    for _ in range(3 * cfg.vocab // scfg.crest_cfg.n_spares):
        eng._steps += 1
        eng._crest_probe()
    rep = eng.crest_report()
    assert rep["confirmed_faults"] >= 3, rep
    assert rep["repaired"] >= 3, rep


# ---------------------------------------------------------------------------
# cache plumbing
# ---------------------------------------------------------------------------

def test_kv_dtype_plumbs_into_stacked_cache(tiny_model):
    cfg, model, params = tiny_model
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32,
                         kv_dtype=jnp.float8_e4m3fn)
    eng = ServeEngine(model, params, ccfg,
                      ServeConfig(max_batch=2, max_len=64, batched=True))
    leaves = jax.tree.leaves(eng.cache)
    kv = [l for l in leaves if l.ndim >= 4]     # (L, B, T, H, D) buffers
    assert kv and all(l.dtype == jnp.float8_e4m3fn for l in kv)
    # prefill-built caches follow too
    toks = jnp.zeros((1, 8), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks}, ccfg, max_len=16)
    assert cache["layers"]["k"].dtype == jnp.float8_e4m3fn


def test_cache_slot_roundtrip(tiny_model):
    """write_cache(cache_at(...)) is the failover handoff primitive: a slot
    written into a stacked grid reads back bit-identical."""
    cfg, model, params = tiny_model
    toks = jnp.asarray(np.arange(8)[None, :], jnp.int32)
    _, sub = model.prefill(params, {"tokens": toks}, CCFG, max_len=16)
    stacked = model.init_cache(4, 16, dtype=jnp.float32)
    stacked = model.write_cache(stacked, sub, 2)
    back = model.cache_at(stacked, 2)
    for a, b in zip(jax.tree.leaves(sub), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    # stack_caches builds the same grid from per-request caches
    restacked = model.stack_caches([model.cache_at(stacked, i) for i in range(4)])
    for a, b in zip(jax.tree.leaves(restacked), jax.tree.leaves(stacked)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_prefill_extend_matches_prefill(tiny_model):
    """Chunked extend over a fresh cache == one-shot prefill (logits of the
    last prompt token and the written K/V both match)."""
    cfg, model, params = tiny_model
    prompt = np.arange(11, dtype=np.int32) % cfg.vocab
    logits_p, cache_p = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None, :])}, CCFG, max_len=16)
    cache = model.init_cache(1, 16, dtype=jnp.float32)
    logits_e = None
    for start in range(0, len(prompt), 4):
        piece = prompt[start:start + 4]
        toks = np.zeros((1, 4), np.int32)
        toks[0, :len(piece)] = piece
        logits_e, cache = model.prefill_extend(
            params, {"tokens": jnp.asarray(toks)}, cache, CCFG,
            n_valid=jnp.int32(len(piece)))
    np.testing.assert_allclose(np.asarray(logits_e), np.asarray(logits_p),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(cache["layers"]["pos"]),
                                  np.asarray(cache_p["layers"]["pos"]))

"""Batched continuous-batching engine: parity, invariants, fault injection.

The batched path must be a pure optimization: token-exact against the
slot-wise reference on every schedule (whole-prompt, chunked prefill,
token-budget interleaving) for EVERY registry arch family — full-attention
KV, MLA latent caches, ring-buffer + recurrent state, SSD state — with
admission/retirement behaving as a FIFO slot grid and CREST probes still
confirming injected faults.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.engine import Request, ServeConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")

CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)


def _load(arch):
    cfg, model = registry.load(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


@pytest.fixture(scope="module")
def tiny_model():
    return _load("codeqwen1.5-7b")


@pytest.fixture(scope="module", params=sorted(registry.FAMILY_SMOKE), ids=str)
def family_model(request):
    """One smoke model per serving family (the CI arch-matrix axis)."""
    return (request.param,) + _load(registry.FAMILY_SMOKE[request.param])


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab, int(n)).astype(np.int32),
                    max_new_tokens=max_new) for i, n in enumerate(lens)]


def _run(model, params, cfg, lens, scfg, max_new=4, seed=0, max_steps=400):
    eng = ServeEngine(model, params, CCFG, scfg)
    reqs = _requests(cfg, lens, max_new=max_new, seed=seed)
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps)
    return reqs, eng


# ---------------------------------------------------------------------------
# parity
# ---------------------------------------------------------------------------

def test_batched_equals_slotwise_token_exact(tiny_model):
    """The tentpole guarantee: one jitted batched decode over the slot grid
    produces exactly the tokens of the per-slot reference loop."""
    cfg, model, params = tiny_model
    lens = [8, 5, 12, 8, 3, 20]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=False))
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=64, batched=True,
                                prefill_chunk=8))
    assert eng.batched
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_chunked_prefill_and_budget_token_exact(tiny_model):
    """Chunked prefill (prompt split across engine steps under a token
    budget) must not change any emitted token."""
    cfg, model, params = tiny_model
    lens = [17, 8, 29, 4]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=False))
    out, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8, token_budget=8))
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_batched_decode_is_single_dispatch(tiny_model):
    """All active slots decode in ONE decode_step call per engine step."""
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=4, max_len=64, batched=True))
    for r in _requests(cfg, [8, 8, 8, 8]):
        eng.submit(r)
    calls = []
    inner = eng._decode_fn
    eng._decode_fn = lambda *a: calls.append(1) or inner(*a)
    eng.step()
    assert sum(s is not None for s in eng.slots) == 4
    assert len(calls) == 1, "batched step must issue one decode dispatch"


# ---------------------------------------------------------------------------
# per-family parity (transformer / moe / griffin / ssm)
# ---------------------------------------------------------------------------

def test_family_batched_equals_slotwise_token_exact(family_model):
    """Every registry arch family decodes token-exact through the stacked
    grid — MLA latent caches, ring buffers + recurrent state, SSD state."""
    fam, cfg, model, params = family_model
    lens = [2, 8, 5, 12, 20, 3]                 # incl. prompt < conv receptive field
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=False))
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=64, batched=True,
                                prefill_chunk=8))
    assert eng.batched, f"{fam} must run the batched fast path"
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (fam, a.uid, a.tokens_out, b.tokens_out)


def test_family_budgeted_chunked_prefill_token_exact(family_model):
    """Chunked prefill under a per-step token budget (prompts split across
    engine steps, interleaved with decode) stays token-exact per family."""
    fam, cfg, model, params = family_model
    lens = [17, 8, 29, 4]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=False))
    out, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8, token_budget=8))
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (fam, a.uid, a.tokens_out, b.tokens_out)


def test_family_failover_clone_token_exact(family_model):
    """Replica death mid-decode: the survivor rebuilds decode state — incl.
    recurrent {conv, h}/{conv, ssd} state — from prompt + emitted tokens."""
    from repro.serve.elastic import ReplicaSet
    fam, cfg, model, params = family_model
    ref, _ = _run(model, params, cfg, [8], ServeConfig(max_batch=1, max_len=64),
                  max_new=8, seed=3)
    scfg = ServeConfig(max_batch=1, max_len=64)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(2)])
    victim = _requests(cfg, [8], max_new=8, seed=3)[0]
    rs.submit(victim)
    for _ in range(3):                         # prefill + a couple of decodes
        rs.step()
    killed_on = next(i for i, e in enumerate(rs.engines) if victim in e.slots)
    rs.kill_replica(killed_on)
    rs.drain(max_steps=200)
    clone = rs.requeued[0]
    assert clone.done
    assert clone.tokens_out == ref[0].tokens_out, (fam, clone.tokens_out,
                                                   ref[0].tokens_out)


def test_moe_parity_under_expert_capacity_pressure():
    """Many concurrent slots routing into few experts: serving dispatch is
    drop-free, so a token's experts never depend on unrelated slot contents
    or chunk boundaries — batched stays token-exact at large batch too
    (with capacity drops, requests diverged at max_batch=12)."""
    cfg, model, params = _load(registry.FAMILY_SMOKE["moe"])
    lens = [8] * 12
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=12, max_len=64, batched=False),
                  max_new=6, max_steps=600)
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=12, max_len=64, batched=True,
                                prefill_chunk=8), max_new=6, max_steps=600)
    assert eng.batched
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


# ---------------------------------------------------------------------------
# ring-buffer edge cases (griffin: windowed attention + recurrent state)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def griffin_w8():
    """Griffin with a tiny window so prompts overrun the ring quickly."""
    cfg, model = registry.load("recurrentgemma-2b", smoke=True)
    cfg = dataclasses.replace(cfg, window=8)
    model = registry.build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


def test_griffin_prompt_longer_than_window_token_exact(griffin_w8):
    """Prompts several times the attention window chunk-prefill through the
    ring without clobbering in-window entries."""
    cfg, model, params = griffin_w8
    lens = [23, 40, 9]                          # all beyond window=8
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=False))
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=64, batched=True,
                                prefill_chunk=4))
    assert eng.batched
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_griffin_chunk_boundary_on_ring_wrap_token_exact(griffin_w8):
    """Chunk == ring length: every chunk boundary lands exactly on the ring
    wrap (the hardest alignment for the drop-scatter write path)."""
    cfg, model, params = griffin_w8
    lens = [16, 24, 17, 8]                      # multiples of window=8 + one off
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=False))
    out, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8))
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_griffin_oversized_chunk_clamped_to_ring(griffin_w8):
    """prefill_chunk larger than the ring is clamped (a chunk must fit the
    ring so within-chunk writes never collide) — still token-exact."""
    cfg, model, params = griffin_w8
    ref, _ = _run(model, params, cfg, [20],
                  ServeConfig(max_batch=1, max_len=64, batched=False))
    out, eng = _run(model, params, cfg, [20],
                    ServeConfig(max_batch=1, max_len=64, batched=True,
                                prefill_chunk=32))
    assert eng._chunk_cap == 8
    assert ref[0].tokens_out == out[0].tokens_out


def test_griffin_window_larger_than_max_len_token_exact():
    """window > max_len: the ring must still hold the FULL window (state is
    O(window), not O(max_len)) — batched chunk-prefill may not silently
    truncate attention relative to the slot-wise whole-prompt baseline."""
    cfg, model = registry.load("recurrentgemma-2b", smoke=True)   # window=16
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    lens = [20, 30, 10]                          # beyond max_len, around window
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=12, batched=False))
    out, eng = _run(model, params, cfg, lens,
                    ServeConfig(max_batch=2, max_len=12, batched=True,
                                prefill_chunk=8))
    assert eng.batched
    for a, b in zip(ref, out):
        assert a.tokens_out == b.tokens_out, (a.uid, a.tokens_out, b.tokens_out)


def test_window_aware_admission_not_spuriously_rejected(griffin_w8):
    """Windowed/recurrent archs hold O(window) state: prompts longer than
    ``max_len`` must be admitted (and never context-limit retired), while
    full-attention archs still reject them."""
    cfg, model, params = griffin_w8
    for batched in (True, False):
        reqs, eng = _run(model, params, cfg, [30, 70],
                         ServeConfig(max_batch=2, max_len=16, batched=batched,
                                     prefill_chunk=8), max_new=5)
        assert all(r.done and len(r.tokens_out) == 5 for r in reqs), (
            batched, [r.tokens_out for r in reqs])
        assert eng.metrics()["requests_rejected"] == 0

    # ssm likewise has no context limit
    cfg_s, model_s, params_s = _load("mamba2-370m")
    reqs, eng = _run(model_s, params_s, cfg_s, [30],
                     ServeConfig(max_batch=1, max_len=16, batched=True,
                                 prefill_chunk=8), max_new=5)
    assert reqs[0].done and len(reqs[0].tokens_out) == 5
    assert eng.metrics()["requests_rejected"] == 0


# ---------------------------------------------------------------------------
# sampling (temperature / top-k)
# ---------------------------------------------------------------------------

def test_sampling_topk1_matches_greedy_batched_and_slotwise(tiny_model):
    """top_k=1 collapses sampling to argmax: token-exact with the greedy
    default in both engine modes (so sampling never perturbs the fast path)."""
    cfg, model, params = tiny_model
    lens = [8, 5, 12]
    ref, _ = _run(model, params, cfg, lens,
                  ServeConfig(max_batch=2, max_len=64, batched=True,
                              prefill_chunk=8))
    for batched in (True, False):
        out, _ = _run(model, params, cfg, lens,
                      ServeConfig(max_batch=2, max_len=64, batched=batched,
                                  prefill_chunk=8, temperature=0.8, top_k=1))
        for a, b in zip(ref, out):
            assert a.tokens_out == b.tokens_out, (batched, a.uid)


def test_sampling_topk_tied_kth_keeps_all_tied_candidates():
    """The documented top-k tie semantics: the truncated support is
    VALUE-defined — every logit >= the k-th largest survives, so a tie at
    the k-th logit keeps MORE than k candidates (no arbitrary index-order
    tie-break). The spec-sampling verify pass relies on plain decode and
    verify sharing this exact truncation (`_truncate_logits` is the single
    implementation both use)."""
    import jax
    import jax.numpy as jnp
    from repro.serve.engine import _sample_tokens, _truncate_logits
    # three-way tie AT the k-th (2nd) largest: candidates 1, 2, 3 all tie
    row = jnp.asarray([[4.0, 1.0, 1.0, 1.0, 0.5, -2.0]], jnp.float32)
    x = np.asarray(_truncate_logits(row, 1.0, 2))
    assert np.isfinite(x[0, :4]).all(), x          # max + all tied kth
    assert not np.isfinite(x[0, 4:]).any(), x      # below kth: masked
    # and the sampler actually reaches every tied candidate (never beyond)
    draws = np.asarray(jax.vmap(
        lambda i: _sample_tokens(row, jax.random.fold_in(
            jax.random.PRNGKey(3), i), 1.0, 2)[0])(jnp.arange(800)))
    assert set(np.unique(draws)) <= {0, 1, 2, 3}
    assert {1, 2, 3} <= set(np.unique(draws)), np.unique(draws)
    # consequence (documented): top_k=1 with a TIED max samples among the
    # tied tokens rather than collapsing to first-index argmax
    tied_max = jnp.asarray([[2.0, 2.0, -1.0]], jnp.float32)
    x1 = np.asarray(_truncate_logits(tied_max, 1.0, 1))
    assert np.isfinite(x1[0, :2]).all() and not np.isfinite(x1[0, 2])


def test_sampling_failover_never_rewrites_emitted_tokens(tiny_model):
    """Failover under temperature sampling: the rebuild carries EVERY
    emitted token in the clone's prompt, so a re-draw on the survivor can
    never rewrite history the client already received."""
    from repro.serve.elastic import ReplicaSet
    cfg, model, params = tiny_model
    scfg = ServeConfig(max_batch=1, max_len=64, temperature=1.0, top_k=8,
                       sample_seed=11)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(2)])
    victim = _requests(cfg, [8], max_new=10, seed=3)[0]
    rs.submit(victim)
    for _ in range(4):                         # prefill + a few decodes
        rs.step()
    emitted = list(victim.tokens_out)
    assert len(emitted) >= 2
    killed_on = next(i for i, e in enumerate(rs.engines) if victim in e.slots)
    rs.kill_replica(killed_on)
    rs.drain(max_steps=200)
    clone = rs.requeued[0]
    assert clone.done and len(clone.tokens_out) == 10
    assert clone.tokens_out[:len(emitted)] == emitted, (
        clone.tokens_out, emitted)


def test_sampling_deterministic_given_seed(tiny_model):
    """Same seed + same schedule => identical samples; tokens stay in-vocab
    and within the top-k support."""
    cfg, model, params = tiny_model
    scfg = ServeConfig(max_batch=2, max_len=64, batched=True, prefill_chunk=8,
                       temperature=1.0, top_k=5, sample_seed=7)
    a, _ = _run(model, params, cfg, [8, 5], scfg, max_new=6)
    b, _ = _run(model, params, cfg, [8, 5], scfg, max_new=6)
    for ra, rb in zip(a, b):
        assert ra.tokens_out == rb.tokens_out
        assert all(0 <= t < cfg.vocab for t in ra.tokens_out)


# ---------------------------------------------------------------------------
# continuous-batching invariants
# ---------------------------------------------------------------------------

def test_fifo_admission_and_slot_reuse(tiny_model):
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True))
    reqs = _requests(cfg, [8] * 5, max_new=3)
    for r in reqs:
        eng.submit(r)
    admitted = []
    for _ in range(100):
        eng.step()
        for s in eng.slots:
            if s is not None and s.uid not in admitted:
                admitted.append(s.uid)
        if not eng.busy():
            break
    assert admitted == [0, 1, 2, 3, 4], "admission must be FIFO"
    assert all(r.done for r in reqs)
    assert all(s is None for s in eng.slots), "retirement must free slots"
    assert not eng.queue


def test_max_new_tokens_retirement_and_queue_drain(tiny_model):
    cfg, model, params = tiny_model
    reqs, eng = _run(model, params, cfg, [8] * 6,
                     ServeConfig(max_batch=3, max_len=64, batched=True),
                     max_new=5)
    assert all(r.done for r in reqs)
    assert all(len(r.tokens_out) == 5 for r in reqs)
    assert not eng.busy()
    m = eng.metrics()
    assert m["requests_finished"] == 6
    assert m["decode_tokens"] == 6 * 4          # first token comes from prefill
    assert m["tokens_per_s"] > 0


def test_eos_retirement_frees_slot_early(tiny_model):
    """Set eos_id to the first decode token of request 0 (learned from a dry
    run): the request must retire early and its slot be reused."""
    cfg, model, params = tiny_model
    probe, _ = _run(model, params, cfg, [8],
                    ServeConfig(max_batch=1, max_len=64, batched=True),
                    max_new=8)
    eos = probe[0].tokens_out[1]                # first *decoded* token
    reqs, eng = _run(model, params, cfg, [8],
                     ServeConfig(max_batch=1, max_len=64, batched=True, eos_id=eos),
                     max_new=8)
    assert reqs[0].done
    assert len(reqs[0].tokens_out) == 2, reqs[0].tokens_out
    assert reqs[0].tokens_out[-1] == eos


def test_budgeted_prefill_interleaves_with_decode(tiny_model):
    """While a long prompt is being chunk-prefilled, an already-resident
    stream must keep producing tokens (bounded decode latency)."""
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True,
                                  prefill_chunk=4, token_budget=4))
    short, long_ = _requests(cfg, [4, 40], max_new=16)
    eng.submit(short)
    eng.step()                                  # short becomes resident
    eng.submit(long_)
    progressed = False
    for _ in range(4):                          # 40-token prompt needs 10 chunks
        before = len(short.tokens_out)
        eng.step()
        if eng._staging is not None and len(short.tokens_out) > before:
            progressed = True
    assert progressed, "decode must advance while a prompt is mid-prefill"
    eng.run_until_drained(200)
    assert short.done and long_.done


def test_evict_and_abort_in_flight(tiny_model):
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True))
    reqs = _requests(cfg, [8, 8, 8], max_new=8)
    for r in reqs:
        eng.submit(r)
    eng.step()
    aborted = eng.abort_in_flight()
    assert {r.uid for r in aborted} == {0, 1}
    assert all(s is None for s in eng.slots)
    assert eng.busy()                           # uid 2 still queued
    eng.run_until_drained(100)
    assert reqs[2].done


def test_failover_clone_continues_token_exact(tiny_model):
    """A request re-queued after replica death must finish with exactly the
    tokens an unkilled run would have produced (greedy decode + idempotent
    regenerate from prompt + emitted prefix)."""
    from repro.serve.elastic import ReplicaSet
    cfg, model, params = tiny_model
    ref, _ = _run(model, params, cfg, [8], ServeConfig(max_batch=1, max_len=64),
                  max_new=8, seed=3)
    scfg = ServeConfig(max_batch=1, max_len=64)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(2)])
    victim = _requests(cfg, [8], max_new=8, seed=3)[0]
    rs.submit(victim)
    for _ in range(3):                         # prefill + a couple of decodes
        rs.step()
    killed_on = next(i for i, e in enumerate(rs.engines) if victim in e.slots)
    rs.kill_replica(killed_on)
    rs.drain(max_steps=200)
    clone = rs.requeued[0]
    assert clone.done
    assert clone.tokens_out == ref[0].tokens_out, (clone.tokens_out, ref[0].tokens_out)


def test_double_failover_still_token_exact(tiny_model):
    """Two successive replica deaths: the rebuild must never double-bake
    emitted tokens into the prompt (prompt_carried bookkeeping)."""
    from repro.serve.elastic import ReplicaSet
    cfg, model, params = tiny_model
    ref, _ = _run(model, params, cfg, [8], ServeConfig(max_batch=1, max_len=64),
                  max_new=10, seed=5)
    scfg = ServeConfig(max_batch=1, max_len=64)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg) for _ in range(3)])
    victim = _requests(cfg, [8], max_new=10, seed=5)[0]
    rs.submit(victim)
    live = victim
    for _ in range(2):                         # kill whichever replica holds it, twice
        for _ in range(3):
            rs.step()
        holder = next(i for i, e in enumerate(rs.engines)
                      if live in e.slots and rs.health[i].alive)
        rs.kill_replica(holder)
        live = rs.requeued[-1]
    rs.drain(max_steps=300)
    assert live.done
    assert live.tokens_out == ref[0].tokens_out, (live.tokens_out, ref[0].tokens_out)


def test_unservable_prompts_rejected_not_crashed(tiny_model):
    """Oversized and empty prompts are rejected at admission (never crash or
    clobber the cache); the queue behind them still drains."""
    cfg, model, params = tiny_model
    for batched in (True, False):
        reqs, eng = _run(model, params, cfg, [30, 0, 6],
                         ServeConfig(max_batch=2, max_len=16, batched=batched,
                                     prefill_chunk=8), max_new=3)
        assert reqs[0].done and reqs[0].tokens_out == []   # too long
        assert reqs[1].done and reqs[1].tokens_out == []   # empty
        assert reqs[2].done and len(reqs[2].tokens_out) == 3
        assert not eng.busy()


# ---------------------------------------------------------------------------
# CREST through the batched path
# ---------------------------------------------------------------------------

def test_crest_confirms_faults_through_batched_engine(tiny_model):
    from repro.core import crest as crest_mod
    cfg, model, params = tiny_model
    scfg = ServeConfig(max_batch=2, max_len=48, batched=True,
                       crest_enabled=True, crest_every=1,
                       crest_cfg=crest_mod.CrestConfig(n_spares=8, threshold=2))
    eng = ServeEngine(model, params, CCFG, scfg)
    assert eng.batched
    eng.fault_mask = crest_mod.inject_column_faults(jax.random.PRNGKey(7), cfg.vocab, 3)
    for r in _requests(cfg, [8] * 4, max_new=16):
        eng.submit(r)
    eng.run_until_drained(200)
    for _ in range(3 * cfg.vocab // scfg.crest_cfg.n_spares):
        eng._steps += 1
        eng._crest_probe()
    rep = eng.crest_report()
    assert rep["confirmed_faults"] >= 3, rep
    assert rep["repaired"] >= 3, rep


# ---------------------------------------------------------------------------
# cache plumbing
# ---------------------------------------------------------------------------

def test_kv_dtype_plumbs_into_stacked_cache(tiny_model):
    cfg, model, params = tiny_model
    ccfg = CascadeConfig(mode="train", compute_dtype=jnp.float32,
                         kv_dtype=jnp.float8_e4m3fn)
    eng = ServeEngine(model, params, ccfg,
                      ServeConfig(max_batch=2, max_len=64, batched=True))
    leaves = jax.tree.leaves(eng.cache)
    kv = [l for l in leaves if l.ndim >= 4]     # (L, B, T, H, D) buffers
    assert kv and all(l.dtype == jnp.float8_e4m3fn for l in kv)
    # prefill-built caches follow too
    toks = jnp.zeros((1, 8), jnp.int32)
    _, cache = model.prefill(params, {"tokens": toks}, ccfg, max_len=16)
    assert cache["layers"]["k"].dtype == jnp.float8_e4m3fn


def test_cache_slot_roundtrip(family_model):
    """write_cache(cache_at(...)) is the failover handoff primitive: a slot
    written into a stacked grid reads back bit-identical — for every cache
    family (probe-discovered slot axes, incl. Python-list sub-caches)."""
    fam, cfg, model, params = family_model
    toks = jnp.asarray(np.arange(8)[None, :], jnp.int32)
    _, sub = model.prefill(params, {"tokens": toks}, CCFG, max_len=16)
    stacked = model.init_cache(4, 16, dtype=jnp.float32)
    stacked = model.write_cache(stacked, sub, 2)
    back = model.cache_at(stacked, 2)
    for a, b in zip(jax.tree.leaves(sub), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    # stack_caches builds the same grid from per-request caches
    restacked = model.stack_caches([model.cache_at(stacked, i) for i in range(4)])
    for a, b in zip(jax.tree.leaves(restacked), jax.tree.leaves(stacked)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_prefill_extend_matches_prefill(family_model):
    """Chunked extend over a fresh cache == one-shot prefill (logits of the
    last prompt token match; KV families also write identical positions)."""
    fam, cfg, model, params = family_model
    prompt = np.arange(11, dtype=np.int32) % cfg.vocab
    logits_p, cache_p = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None, :])}, CCFG, max_len=16)
    cache = model.init_cache(1, 16, dtype=jnp.float32)
    logits_e = None
    for start in range(0, len(prompt), 4):
        piece = prompt[start:start + 4]
        toks = np.zeros((1, 4), np.int32)
        toks[0, :len(piece)] = piece
        logits_e, cache = model.prefill_extend(
            params, {"tokens": jnp.asarray(toks)}, cache, CCFG,
            n_valid=jnp.int32(len(piece)))
    # recurrent scans reassociate across chunk boundaries -> fp-level slack
    tol = 1e-5 if fam in ("transformer", "moe") else 1e-4
    np.testing.assert_allclose(np.asarray(logits_e), np.asarray(logits_p),
                               atol=tol, rtol=tol)
    if fam == "transformer":
        np.testing.assert_array_equal(np.asarray(cache["layers"]["pos"]),
                                      np.asarray(cache_p["layers"]["pos"]))


# ---------------------------------------------------------------------------
# per-request latency telemetry (the injected clock)
# ---------------------------------------------------------------------------

def test_per_token_telemetry_and_latency_percentiles(tiny_model):
    """Every committed token carries a clock stamp: token_times parallels
    tokens_out, first_token_at is the first stamp, and metrics() exposes
    TTFT / inter-token percentiles computed from retired requests."""
    from repro.serve.traffic import VirtualClock
    cfg, model, params = tiny_model
    clk = VirtualClock(start=100.0)
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True),
                      clock=clk)
    reqs = _requests(cfg, [8, 8, 8], max_new=4)
    for r in reqs:
        eng.submit(r)
        assert r.created_at == 100.0            # stamped on submit
    while eng.busy():
        clk.advance(0.5)                        # harness-advanced time
        eng.step()
    for r in reqs:
        assert r.done
        assert len(r.token_times) == len(r.tokens_out)
        assert r.first_token_at == r.token_times[0] > r.created_at
        assert all(b >= a for a, b in zip(r.token_times, r.token_times[1:]))
    m = eng.metrics()
    for k in ("ttft_p50_s", "ttft_p99_s",
              "inter_token_p50_s", "inter_token_p99_s"):
        assert m[k] > 0.0, k
    assert m["ttft_p50_s"] <= m["ttft_p99_s"]
    assert m["inter_token_p50_s"] <= m["inter_token_p99_s"]


def test_spec_commit_burst_has_zero_intra_run_gaps(tiny_model):
    """Speculative decode commits a whole accepted run at ONE instant:
    intra-run inter-token gaps are honestly 0 (a client sees the burst),
    and the telemetry must record that rather than fabricate spacing."""
    from repro.serve.traffic import VirtualClock
    cfg, model, params = tiny_model
    clk = VirtualClock()
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=1, max_len=128, batched=True,
                                  draft_len=4),
                      clock=clk)
    rng = np.random.default_rng(0)
    pat = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    eng.submit(Request(uid=0, prompt=np.tile(pat, 4), max_new_tokens=12))
    while eng.busy():
        clk.advance(1.0)
        eng.step()
    req = eng._retired[-1]
    assert eng.spec and len(req.token_times) == len(req.tokens_out)
    gaps = [b - a for a, b in zip(req.token_times, req.token_times[1:])]
    assert any(g == 0.0 for g in gaps), "accepted runs commit at one instant"
    assert all(g in (0.0, 1.0) for g in gaps)


def test_rejected_requests_excluded_from_latency_stats(tiny_model):
    """Rejected (never-served) requests have no first token; they must not
    poison the TTFT percentiles but must count in requests_rejected."""
    cfg, model, params = tiny_model
    reqs, eng = _run(model, params, cfg, [0, 8],
                     ServeConfig(max_batch=1, max_len=64, batched=True),
                     max_new=3)
    m = eng.metrics()
    assert m["requests_rejected"] == 1
    assert m["requests_finished"] == 1
    assert reqs[0].first_token_at == 0.0 and reqs[0].token_times == []
    assert m["ttft_p50_s"] > 0.0                # from the served request only

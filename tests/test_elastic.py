"""Direct ReplicaSet coverage: dispatch, demotion, drain, failover carry.

The replica controller was previously exercised only through failover
parity tests; these pin its own contracts — least-loaded submit, the
straggler DEMOTION fix (the old fleet-median check could never fire with
2 replicas, and a drained straggler immediately won the next least-loaded
submit), EWMA recovery, drain termination with dead replicas, and
``requeued``/telemetry bookkeeping across multiple kills — all under the
injected VirtualClock + ``step_cost`` so every number is deterministic.
"""
import jax
import numpy as np
import pytest

from repro.core.cascade import CascadeConfig
from repro.models import registry
from repro.serve.elastic import ReplicaSet, rebuild_request
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.traffic import VirtualClock

import jax.numpy as jnp

jax.config.update("jax_platform_name", "cpu")

CCFG = CascadeConfig(mode="train", compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def tiny_model():
    cfg, model = registry.load("codeqwen1.5-7b", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0), CCFG)
    return cfg, model, params


def _fleet(tiny_model, n=2, max_batch=2, step_cost=None, clock=None):
    cfg, model, params = tiny_model
    clk = clock if clock is not None else VirtualClock()
    scfg = ServeConfig(max_batch=max_batch, max_len=64, batched=True,
                       prefill_chunk=8)
    rs = ReplicaSet([ServeEngine(model, params, CCFG, scfg, clock=clk)
                     for _ in range(n)],
                    clock=clk, step_cost=step_cost)
    return cfg, rs


def _reqs(cfg, n, max_new=4, seed=0, start_uid=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=start_uid + i,
                    prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def test_submit_least_loaded_invariant(tiny_model):
    """Every submit targets a replica whose pre-submit load was minimal
    among eligible replicas — checked under a mixed load pattern."""
    cfg, rs = _fleet(tiny_model, n=3, step_cost=lambda i: 0.01)
    for r in _reqs(cfg, 9):
        loads = [e.load() for e in rs.engines]
        i = rs.submit(r)
        assert loads[i] == min(loads), (i, loads)
        if r.uid % 3 == 2:          # interleave steps: loads diverge
            rs.step()
    rs.drain(max_steps=500)
    # the work actually spread: no replica served everything
    served = [len(e._retired) for e in rs.engines]
    assert sum(served) == 9 and max(served) < 9, served


def test_replica_ewma_equals_virtual_step_cost(tiny_model):
    """Under ``step_cost`` the replica EWMA — the demotion signal — equals
    the configured cost exactly (the clock is advanced by exactly that
    much around each step), deterministically."""
    cfg, rs = _fleet(tiny_model, n=2, step_cost=lambda i: 0.02 * (i + 1))
    for r in _reqs(cfg, 4):
        rs.submit(r)
    rs.drain(max_steps=200)
    for i, h in enumerate(rs.health):
        assert h.steps > 0
        assert h.ewma_ms == pytest.approx(20.0 * (i + 1))


def test_straggler_p99_reads_step_times(tiny_model):
    """``straggler_p99`` is the 99th percentile of the engine's recorded
    step times — positive and consistent under the wall clock."""
    cfg, model, params = tiny_model
    eng = ServeEngine(model, params, CCFG,
                      ServeConfig(max_batch=2, max_len=64, batched=True,
                                  prefill_chunk=8))
    for r in _reqs(cfg, 2):
        eng.submit(r)
    eng.run_until_drained(200)
    assert eng.step_times
    assert eng.straggler_p99() > 0.0
    assert eng.straggler_p99() == pytest.approx(
        float(np.percentile(np.asarray(eng.step_times), 99)))


# ---------------------------------------------------------------------------
# straggler demotion (the satellite fix)
# ---------------------------------------------------------------------------

def test_slow_replica_demoted_and_stops_receiving(tiny_model):
    """Regression for the fleet-median bug: with 2 replicas the old check
    (ewma > factor * median-of-all) was unsatisfiable, so a straggler kept
    winning least-loaded submits with its drained queue. Now: the slow
    replica demotes and NEW work all lands on the fast one."""
    cfg, rs = _fleet(tiny_model, n=2, max_batch=4,
                     step_cost=lambda i: 1.0 if i == 0 else 0.01)
    # warm both EWMAs past the steps>4 guard with resident work
    for r in _reqs(cfg, 2, max_new=64):
        rs.submit(r)
    for _ in range(8):
        rs.step()
    assert rs.health[0].demoted and not rs.health[1].demoted
    # demoted replica receives nothing new, even while loaded less
    sinks = {rs.submit(r) for r in _reqs(cfg, 4, start_uid=100)}
    assert sinks == {1}


def test_demotion_reroutes_queued_work(tiny_model):
    """Demotion moves the straggler's queued-but-unadmitted requests to
    faster replicas; resident work stays and finishes in place."""
    cfg, rs = _fleet(tiny_model, n=2, max_batch=1,
                     step_cost=lambda i: 1.0 if i == 0 else 0.01)
    # saturate both replicas, then overflow replica 0's queue
    for r in _reqs(cfg, 2, max_new=64):
        rs.submit(r)
    extra = _reqs(cfg, 1, start_uid=50)[0]
    rs.engines[0].submit(extra)
    assert extra in rs.engines[0].queue
    for _ in range(8):
        rs.step()
    assert rs.health[0].demoted
    assert extra not in rs.engines[0].queue      # re-routed on demotion
    assert extra in rs.engines[1].queue


def test_demoted_replica_recovers(tiny_model):
    """EWMA back under the bar (resident work stepping at the improved
    cost) flips ``demoted`` off and the replica is dispatchable again."""
    cost = {"slow": True}
    cfg, rs = _fleet(tiny_model, n=2, max_batch=4,
                     step_cost=lambda i: (1.0 if cost["slow"] else 0.01)
                     if i == 0 else 0.01)
    for r in _reqs(cfg, 2, max_new=200):
        rs.submit(r)
    for _ in range(8):
        rs.step()
    assert rs.health[0].demoted
    cost["slow"] = False                          # straggler heals
    for _ in range(80):
        rs.step()
        if not rs.health[0].demoted:
            break
    assert not rs.health[0].demoted
    rs.drain(max_steps=1000)


def test_never_demote_last_dispatch_target(tiny_model):
    """With one replica alive there is nothing to compare against — it
    must stay dispatchable no matter how slow it is."""
    cfg, rs = _fleet(tiny_model, n=2, step_cost=lambda i: 1.0)
    for r in _reqs(cfg, 2, max_new=16):
        rs.submit(r)
    rs.kill_replica(1)
    for _ in range(8):
        rs.step()
    assert rs.health[0].alive and not rs.health[0].demoted
    rs.drain(max_steps=500)
    assert sum(len(e._retired) for e in rs.engines) == 2


# ---------------------------------------------------------------------------
# drain + multi-kill bookkeeping
# ---------------------------------------------------------------------------

def test_drain_terminates_with_dead_replicas(tiny_model):
    """A dead replica's busy() state must not wedge drain."""
    cfg, rs = _fleet(tiny_model, n=3, step_cost=lambda i: 0.01)
    for r in _reqs(cfg, 6):
        rs.submit(r)
    rs.step()
    rs.kill_replica(0)
    rs.kill_replica(2)
    rs.drain(max_steps=500)
    assert all(not e.busy() or not h.alive
               for e, h in zip(rs.engines, rs.health))
    # the sole survivor served every stream (re-routed or fresh)
    assert {r.uid for r in rs.engines[1]._retired} == set(range(6))


def test_requeued_bookkeeping_after_multi_kill(tiny_model):
    """Every mid-decode stream killed (possibly twice) appears in
    ``requeued`` as a telemetry-carrying clone; prompt_carried stops
    double-baking tokens across repeated failures."""
    cfg, rs = _fleet(tiny_model, n=3, max_batch=4,
                     step_cost=lambda i: 0.01)
    reqs = _reqs(cfg, 6, max_new=32)
    for r in reqs:
        rs.submit(r)
    for _ in range(3):
        rs.step()
    rs.kill_replica(0)
    for _ in range(2):
        rs.step()
    rs.kill_replica(1)
    rs.drain(max_steps=2000)
    assert rs.requeued, "kills mid-decode must create failover clones"
    for clone in rs.requeued:
        assert clone.prompt_carried == len(clone.prompt) - 8   # orig prompt 8
        assert clone.created_at > 0.0                          # carried
        assert len(clone.token_times) >= clone.prompt_carried
    # zero lost tokens: the survivor finished every stream exactly once
    final = {}
    for e in rs.engines:
        for r in e._retired:
            cur = final.get(r.uid)
            if cur is None or len(r.tokens_out) > len(cur.tokens_out):
                final[r.uid] = r
    assert set(final) == {r.uid for r in reqs}
    for r in final.values():
        assert len(r.tokens_out) == 32 and r.done


def test_rebuild_request_carries_latency_telemetry(tiny_model):
    """The failover clone's latency record spans replicas: arrival time,
    first-token time and committed token timestamps all carry over, and
    engine.submit must NOT re-stamp the carried created_at."""
    cfg, rs = _fleet(tiny_model, n=2, step_cost=lambda i: 0.01)
    req = _reqs(cfg, 1, max_new=16)[0]
    req.created_at = 1.5                   # open-loop pre-stamped arrival
    rs.submit(req)
    for _ in range(4):
        rs.step()
    assert req.token_times and req.first_token_at > 0.0
    clone = rebuild_request(req)
    assert clone is not req
    assert clone.created_at == 1.5
    assert clone.first_token_at == req.first_token_at
    assert clone.token_times == req.token_times
    rs.engines[1].submit(clone)
    assert clone.created_at == 1.5         # submit kept the carried stamp

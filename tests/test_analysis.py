"""Tests for the roofline analysis pipeline: HLO trip-count correction,
analytic FLOPs/params model vs real param trees, shape applicability, and a
subprocess end-to-end dry-run cell."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_hlo_analysis_corrects_scan_trip_count():
    """cost_analysis counts a while body once; the structural analyzer must
    recover trip_count x body dot FLOPs exactly."""
    sys.path.insert(0, REPO)
    from benchmarks import hlo_analysis

    L, M, K = 12, 32, 64

    def f(x, ws):
        def step(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(step, x, ws)
        return out.sum()

    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                            jax.ShapeDtypeStruct((L, K, K), jnp.float32)).compile()
    r = hlo_analysis.analyze(comp.as_text())
    expected = L * 2 * M * K * K
    assert r["dot_flops"] == expected, (r["dot_flops"], expected)
    # and the uncorrected number is exactly one iteration
    assert r["dot_flops_uncorrected"] == expected / L


def test_param_count_matches_real_init():
    """Analytic param_count (used for MODEL_FLOPS) vs the actual full-config
    param tree, via eval_shape (no allocation)."""
    from repro.core import flops as F
    from repro.core.cascade import CascadeConfig
    from repro.models import registry

    ccfg = CascadeConfig(mode="train")
    for arch in ["qwen2.5-32b", "phi4-mini-3.8b", "olmoe-1b-7b", "mamba2-370m",
                 "deepseek-v2-236b", "recurrentgemma-2b", "musicgen-large"]:
        cfg, model = registry.load(arch)
        shapes = jax.eval_shape(lambda m=model: m.init_params(jax.random.PRNGKey(0), ccfg))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        analytic = F.param_count(cfg)["total"]
        rel = abs(actual - analytic) / actual
        assert rel < 0.015, f"{arch}: analytic {analytic:.3e} vs actual {actual:.3e} ({rel:.3%})"


def test_known_param_totals():
    """Sanity anchors: the archs' nominal sizes."""
    from repro.core import flops as F
    from repro.models import registry
    expect = {"qwen2.5-32b": (30e9, 36e9), "phi4-mini-3.8b": (3.3e9, 4.4e9),
              "deepseek-v2-236b": (220e9, 250e9), "olmoe-1b-7b": (6.0e9, 7.5e9),
              "mamba2-370m": (0.3e9, 0.45e9), "nemotron-4-15b": (14e9, 17e9)}
    for arch, (lo, hi) in expect.items():
        total = F.param_count(registry.get_config(arch))["total"]
        assert lo < total < hi, f"{arch}: {total:.3e} outside [{lo:.1e},{hi:.1e}]"
    ds = F.param_count(registry.get_config("deepseek-v2-236b"))
    assert 18e9 < ds["active"] < 25e9  # DeepSeek-V2: ~21B active


def test_shape_applicability_covers_40_cells():
    from repro.configs import base as cfgbase
    from repro.models import registry
    cells = [(a, s) for a in registry.ALIASES for s in cfgbase.SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells if cfgbase.shape_applicable(
        registry.get_config(c[0]), cfgbase.SHAPES[c[1]])]
    skipped = [c for c in cells if c not in runnable]
    assert len(runnable) == 32 and len(skipped) == 8  # 8 full-attn archs skip long_500k
    # exactly the sub-quadratic archs keep long_500k
    keep = {a for (a, s) in runnable if s == "long_500k"}
    assert keep == {"mamba2-370m", "recurrentgemma-2b"}


def test_input_specs_all_cells_no_allocation():
    from repro.configs import base as cfgbase
    from repro.models import registry
    for a in registry.ALIASES:
        cfg = registry.get_config(a)
        for s in cfgbase.SHAPES.values():
            specs = cfgbase.input_specs(cfg, s)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
            if cfg.input_embeds:
                assert "inputs_embeds" in specs and "tokens" not in specs


@pytest.mark.slow
def test_dryrun_cell_subprocess_single_and_megatron():
    """End-to-end: a real dry-run cell on a 256-virtual-device mesh in a
    fresh interpreter (XLA_FLAGS must be set before jax init), both TP
    policies."""
    for policy in ["cascade", "megatron"]:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "qwen2-vl-2b", "--shape", "decode_32k",
             "--tp-policy", policy],
            cwd=REPO, env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
            capture_output=True, text=True, timeout=900)
        assert proc.returncode == 0, proc.stdout[-800:] + proc.stderr[-800:]
        rec = json.loads([l for l in proc.stdout.splitlines() if l.startswith("{")][0])
        assert rec["status"] == "ok" and rec["tp_policy"] == policy
        assert rec["memory"]["peak_bytes"] < 16e9


@pytest.mark.slow
def test_train_and_serve_cli_subprocess():
    """The launchers run end-to-end from their CLIs (the deployment path)."""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    t = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "phi4-mini-3.8b",
         "--smoke", "--steps", "6", "--batch", "2", "--seq", "32", "--qat"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert t.returncode == 0 and "final loss" in t.stdout, t.stdout[-400:] + t.stderr[-400:]
    s = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "codeqwen1.5-7b",
         "--smoke", "--requests", "3", "--max-batch", "2", "--max-new", "4"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert s.returncode == 0 and "served 3 requests" in s.stdout, s.stdout[-400:] + s.stderr[-400:]


# ---------------------------------------------------------------------------
# report.py loaders: schema tolerance
# ---------------------------------------------------------------------------

def test_report_load_tolerates_missing_keys(tmp_path):
    """Rows from older sweeps may lack arch/shape; load() must key them
    under '?' instead of KeyError-ing the whole report away."""
    sys.path.insert(0, REPO)
    from benchmarks import report

    p = tmp_path / "roof.json"
    p.write_text(json.dumps([
        {"arch": "a1", "shape": "s1", "status": "ok"},
        {"status": "ok", "decode_bound_tokens_per_s": 5.0},   # legacy row
    ]))
    d = report.load(str(p))
    assert ("a1", "s1") in d and ("?", "?") in d
    assert report.load(str(tmp_path / "missing.json")) == {}


def test_report_fused_table_tolerates_missing_bound_fields():
    """A fused row without weight_stream_bytes_per_device renders with a
    0.00 GB cell — the table never drops because one field is absent."""
    sys.path.insert(0, REPO)
    from benchmarks import report

    rows = [
        {"mode": "fused", "family": "transformer", "max_batch": 2,
         "tokens_per_s": 100.0, "decode_bound_tokens_per_s": 1000.0,
         "fraction_of_bound": 0.1},                # no weight_stream bytes
        {"mode": "fp4", "family": "transformer", "max_batch": 2,
         "tokens_per_s": 80.0},
    ]
    lines = report.fused_lines(rows)
    row = [l for l in lines if l.startswith("| transformer")]
    assert len(row) == 1
    assert "0.00" in row[0] and "100.0" in row[0] and "80.0" in row[0]
    assert report.fused_lines([{"mode": "batched"}]) == []


def test_report_load_tolerates_prefix_row_keys(tmp_path):
    """The paged/prefix rows add keys (prefix_hit_rate, ttft_warm_p50_s,
    prefix_probe shape) no earlier loader knew about; load() must keep
    keying them by (arch, shape) without complaint."""
    sys.path.insert(0, REPO)
    from benchmarks import report

    p = tmp_path / "serving.json"
    p.write_text(json.dumps([
        {"arch": "a1", "shape": "prefix_probe", "mode": "paged",
         "status": "ok", "prefix_hit_rate": 0.5, "ttft_cold_s": 0.06,
         "ttft_warm_s": 0.02},
        {"arch": "a1", "shape": "serve_decode_b2", "mode": "paged",
         "status": "ok", "pages_in_use": 12, "page_size": 16},
    ]))
    d = report.load(str(p))
    assert ("a1", "prefix_probe") in d
    assert d[("a1", "prefix_probe")]["prefix_hit_rate"] == 0.5


def test_report_prefix_table_renders_both_sources():
    """prefix_lines joins serving prefix_probe rows with *-prefix traffic
    rows; rows missing any new key render dashes, never KeyError."""
    sys.path.insert(0, REPO)
    from benchmarks import report

    serving = [
        {"shape": "prefix_probe", "family": "transformer", "prefix_len": 64,
         "ttft_cold_s": 0.0639, "ttft_warm_s": 0.0199,
         "prefix_hit_rate": 0.38, "pages_in_use": 12, "evictions": 0},
        {"shape": "serve_decode_b2", "mode": "batched"},   # not a prefix row
    ]
    traffic = [
        {"mode": "traffic-virtual-prefix", "family": "transformer",
         "shared_prefix_len": 64, "ttft_cold_p50_s": 0.0191,
         "ttft_warm_p50_s": 0.0168, "prefix_hit_rate": 0.749,
         "pages_in_use": 27, "evictions": 0},
        {"mode": "traffic-virtual", "family": "transformer"},  # no prefix keys
    ]
    lines = report.prefix_lines(serving, traffic)
    probe = [l for l in lines if l.startswith("| probe")]
    traf = [l for l in lines if l.startswith("| traffic")]
    assert len(probe) == 1 and len(traf) == 1
    assert "63.9" in probe[0] and "19.9" in probe[0] and "3.21x" in probe[0]
    assert "19.1" in traf[0] and "16.8" in traf[0] and "virtual" in traf[0]
    # a traffic row missing the warm/cold keys but tagged -prefix still
    # renders (as dashes) rather than KeyError-ing
    lines2 = report.prefix_lines([], [{"mode": "traffic-wall-prefix"}])
    assert any("| traffic (wall)" in l and "—" in l for l in lines2)
    # and with no prefix rows anywhere the table is absent entirely
    assert report.prefix_lines([{"mode": "batched"}],
                               [{"mode": "traffic-virtual"}]) == []


# ---------------------------------------------------------------------------
# HLO parser corner cases (repro.analysis.hlo — benchmarks/hlo_analysis is
# the import shim over it)
# ---------------------------------------------------------------------------

# hand-written module fragments exercising the exact syntax the checkers
# key on; real lowerings around them are covered by test_audit.py
_ASYNC_AR_HLO = """\
HloModule m

%add_comb (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add.1 = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8] parameter(0)
  %ars = f32[8] all-reduce-start(f32[8] %p0), to_apply=%add_comb
  ROOT %ard = f32[8] all-reduce-done(f32[8] %ars)
}
"""


def test_async_allreduce_start_done_counted_once():
    """An async all-reduce appears as a -start/-done PAIR; both the
    loop-corrected census and the partial-sum gate must count the pair as
    ONE collective (the -start carries the combiner; double-counting would
    fail budgets that are actually met)."""
    from repro.analysis import hlo

    r = hlo.analyze(_ASYNC_AR_HLO)
    assert r["collectives"]["all-reduce"]["count"] == 1
    assert r["collectives"]["all-reduce"]["bytes"] == 32  # f32[8], once
    ps = hlo.partial_sum_allreduces(_ASYNC_AR_HLO)
    assert ps["count"] == 1 and ps["bytes"] == 32


def test_variadic_tuple_combiner_is_partial_sum():
    """XLA's combiner pass merges several all-reduces into one variadic op
    whose reduction computation ROOTs a tuple OF adds — containment, not
    root-op equality, must classify it as a partial sum. A max combiner in
    the same module stays unclassified (argmax lowerings are not partial
    products)."""
    from repro.analysis import hlo

    text = """\
HloModule m

%var_comb (a0: f32[], b0: f32[], a1: f32[], b1: f32[]) -> (f32[], f32[]) {
  %a0 = f32[] parameter(0)
  %b0 = f32[] parameter(1)
  %a1 = f32[] parameter(2)
  %b1 = f32[] parameter(3)
  %add.a = f32[] add(f32[] %a0, f32[] %a1)
  %add.b = f32[] add(f32[] %b0, f32[] %b1)
  ROOT %t = (f32[], f32[]) tuple(%add.a, %add.b)
}

%max_comb (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %max.1 = f32[] maximum(f32[] %a, f32[] %b)
}

ENTRY %main (x: f32[16], y: f32[16]) -> (f32[16], f32[16]) {
  %x = f32[16] parameter(0)
  %y = f32[16] parameter(1)
  %armax = f32[16] all-reduce(f32[16] %x), to_apply=%max_comb
  ROOT %ar = (f32[16], f32[16]) all-reduce(f32[16] %x, f32[16] %y), to_apply=%var_comb
}
"""
    ps = hlo.partial_sum_allreduces(text)
    assert ps["count"] == 1, ps["ops"]           # the max combiner is not one
    assert ps["bytes"] == 128                    # both tuple halves counted
    assert ps["ops"][0][0].endswith("/ar")


def test_while_without_known_trip_count_counts_body_once():
    """A while op the compiler could not bound has no known_trip_count
    attribute; the multiplicity walk must fall back to trip=1 (body once,
    condition twice) rather than KeyError or drop the body's FLOPs — and
    the same module WITH the attribute scales exactly by it."""
    from repro.analysis import hlo

    tmpl = """\
HloModule m

%body (p: (f32[4,4], f32[4,4])) -> (f32[4,4], f32[4,4]) {
  %p = (f32[4,4], f32[4,4]) parameter(0)
  %c = f32[4,4] get-tuple-element((f32[4,4], f32[4,4]) %p), index=0
  %w = f32[4,4] get-tuple-element((f32[4,4], f32[4,4]) %p), index=1
  %d = f32[4,4] dot(f32[4,4] %c, f32[4,4] %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = (f32[4,4], f32[4,4]) tuple(%d, %w)
}

%cond (p: (f32[4,4], f32[4,4])) -> pred[] {
  %p = (f32[4,4], f32[4,4]) parameter(0)
  ROOT %k = pred[] constant(false)
}

ENTRY %main (x: (f32[4,4], f32[4,4])) -> (f32[4,4], f32[4,4]) {
  %x = (f32[4,4], f32[4,4]) parameter(0)
  ROOT %w.1 = (f32[4,4], f32[4,4]) while((f32[4,4], f32[4,4]) %x), condition=%cond, body=%body{TRIP}
}
"""
    body_flops = 2 * 4 * 4 * 4              # one 4x4 @ 4x4
    unbounded = tmpl.replace("{TRIP}", "")
    comps, entry = hlo.parse_computations(unbounded)
    mult = hlo._multiplicities(comps, entry)
    assert mult["body"] == 1.0 and mult["cond"] == 2.0
    assert hlo.analyze(unbounded)["dot_flops"] == body_flops

    bounded = tmpl.replace(
        "{TRIP}", ', backend_config={"known_trip_count":{"n":"12"}}')
    comps, entry = hlo.parse_computations(bounded)
    mult = hlo._multiplicities(comps, entry)
    assert mult["body"] == 12.0 and mult["cond"] == 13.0
    assert hlo.analyze(bounded)["dot_flops"] == 12 * body_flops


def test_hlo_shim_reexports_checkers():
    """benchmarks/hlo_analysis stays importable with its full pre-move
    surface — plus the new checkers — so stored scripts keep running."""
    sys.path.insert(0, REPO)
    from benchmarks import hlo_analysis
    from repro.analysis import hlo

    for name in ("analyze", "parse_computations", "partial_sum_allreduces",
                 "donation_aliases", "host_transfers", "dtype_audit",
                 "collective_budget", "_multiplicities"):
        assert getattr(hlo_analysis, name) is getattr(hlo, name), name


# ---------------------------------------------------------------------------
# report.py: serving-contract audit table
# ---------------------------------------------------------------------------

def test_report_audit_table_renders_and_tolerates_sparse_cells():
    """audit_lines renders the benchmarks/audit.py artifact; cells from
    older runs may lack closures/findings/summary and must render dashes,
    never KeyError. No artifact at all -> no table."""
    sys.path.insert(0, REPO)
    from benchmarks import report

    data = {
        "lint": [{"check": "jax-config-global", "where": "engine.py:381",
                  "detail": "x", "level": "error", "allowlisted": True}],
        "cells": [
            {"family": "transformer", "mode": "dense", "placement": "single",
             "status": "audited",
             "closures": {"decode": {"donation_aliases": 3,
                                     "host_transfers": 0,
                                     "partial_sum_allreduces": 0}},
             "findings": [{"check": "donation", "where": "decode",
                           "detail": "d", "level": "error",
                           "allowlisted": False}]},
            {"family": "griffin", "mode": "paged", "placement": "single",
             "status": "downgraded"},           # sparse legacy cell
        ],
        "summary": {"audited": 1, "downgraded": 1, "gating": 1},
    }
    lines = report.audit_lines(data)
    tr = [l for l in lines if l.startswith("| transformer")]
    gr = [l for l in lines if l.startswith("| griffin")]
    assert len(tr) == 1 and len(gr) == 1
    assert "| 1 | 3 | 0 | 0 | 1/0/0 |" in tr[0]
    assert "downgraded" in gr[0] and "—" in gr[0]
    assert any("0 gating, 1 allowlisted" in l for l in lines)
    assert any("1 audited + 1 downgrade-verified" in l for l in lines)
    assert report.audit_lines({}) == []
    assert report.audit_lines({"cells": []}) == []


def test_report_audit_data_tolerates_missing_and_broken_files(tmp_path):
    sys.path.insert(0, REPO)
    from benchmarks import report

    assert report.audit_data(str(tmp_path / "nope.json")) == {}
    p = tmp_path / "broken.json"
    p.write_text("{not json")
    assert report.audit_data(str(p)) == {}
    p.write_text(json.dumps([1, 2, 3]))       # wrong top-level type
    assert report.audit_data(str(p)) == {}
    p.write_text(json.dumps({"cells": [], "lint": []}))
    assert report.audit_data(str(p)) == {"cells": [], "lint": []}
